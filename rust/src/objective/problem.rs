//! The partition-native problem layer: ship dataset **shards**, not
//! rebuild recipes.
//!
//! The paper's premise is that no single machine holds the full dataset
//! (§1, §4.2): each of the `m` machines stores only its random partition,
//! O(n/m) elements.  The process/tcp backends originally shipped a flat
//! problem *spec* and had every worker regenerate the entire dataset
//! before restricting to its part — O(n) memory per worker, which caps
//! the `dist` layer at what one host can regenerate.  This module is the
//! API that removes that cap:
//!
//! * [`PartitionPayload`] — a serde-stable shard of one oracle's dataset:
//!   the global ids of the shipped elements plus their renumbered,
//!   worker-locally-dense data (`offsets`/`items` CSR for the coverage
//!   family, row-major `f32` for vectors, benefit columns for facility
//!   location, weights for modular).
//! * [`Partitionable`] — the extraction half, implemented by every CPU
//!   oracle: [`Partitionable::extract_partition`] slices the payload for
//!   an arbitrary element list (a leaf partition at Init, a shipped
//!   solution at Ship).
//! * [`PartitionOracle`] — the rebuild half: an [`Oracle`] facade a worker
//!   constructs from a payload.  Internally the data is renumbered into a
//!   dense local ground set `0..len_local` with an id map back to global
//!   [`ElemId`]s; **externally the facade speaks global ids** — `n()` is
//!   the global ground-set size and every gain/commit/`elem_bytes` call
//!   translates through the id map.  Keeping the algorithm layer in
//!   global-id space is what preserves bit-parity with the thread
//!   backend: lazy-greedy tie-breaking, `dedup_candidates`, partition
//!   matroid group assignment and §6.4 added-element draws all key on id
//!   *values*, so renumbering must never leak past the data access.
//!
//! A worker's shard grows over the run: child solutions arriving for an
//! accumulation step carry their own extracted payloads
//! ([`crate::dist::node::ChildMsg::data`]), which the parent
//! [`PartitionOracle::ingest`]s before running GREEDY on the union — the
//! exact data movement §4.2's communication complexity accounts for.

use super::{GainState, Oracle};
use crate::util::bitset::BitSet;
use crate::ElemId;
use serde_json::{json, Value};
use std::collections::HashMap;
use std::sync::Arc;

/// One oracle family's sliced dataset, renumbered to the shard's local
/// dense id space (element `i` of the payload is local id `i`; its global
/// id is `PartitionPayload::elems[i]`).
#[derive(Clone, Debug, PartialEq)]
pub enum PartitionData {
    /// Coverage family (k-cover, weighted cover, k-dominating-set):
    /// per-element item lists in CSR form over a fixed *global* item
    /// universe (for k-dominating-set the "items" are global vertex ids
    /// and `universe` is the global vertex count).
    Cover {
        /// Size of the global item universe (bitmap width of a state).
        universe: usize,
        /// CSR offsets, `len_local + 1` entries.
        offsets: Vec<u64>,
        /// Concatenated sorted item lists.
        items: Vec<u32>,
        /// `(item, weight)` pairs for every item appearing in `items`
        /// (weighted cover); `None` = unit weights.
        weights: Option<Vec<(u32, f64)>>,
        /// Each element additionally covers its own global id
        /// (closed-neighbourhood k-dominating-set).
        self_cover: bool,
        /// Rebuild under the "k-dominating-set" name (reporting only —
        /// the gain math is shared with k-cover).
        dominating: bool,
    },
    /// Dense vectors (k-medoid): row-major `f32`, one row per element.
    Vectors {
        /// Row dimensionality.
        dim: usize,
        /// `len_local * dim` floats.
        flat: Vec<f32>,
    },
    /// Facility location: one benefit column per element.
    Facility {
        /// Number of clients (rows of the global benefit matrix).
        clients: usize,
        /// `len_local * clients` benefits, element-major
        /// (`columns[e * clients + c]`).
        columns: Vec<f64>,
    },
    /// Modular: one weight per element.
    Modular {
        /// `len_local` weights.
        weights: Vec<f64>,
    },
}

/// A serde-stable shard of a problem: which global elements it holds and
/// their renumbered data.  This is what crosses the wire in
/// `InitPart` frames and inside shipped child solutions.
#[derive(Clone, Debug, PartialEq)]
pub struct PartitionPayload {
    /// Global ground-set size `n` (the id-space bound; [`Oracle::n`] of
    /// the rebuilt facade).
    pub n_global: usize,
    /// Global ids of the shipped elements, in shard order — the id map
    /// back from the local dense ground set.
    pub elems: Vec<ElemId>,
    /// The renumbered per-family data.
    pub data: PartitionData,
}

impl PartitionPayload {
    /// Number of elements in this shard.
    pub fn len(&self) -> usize {
        self.elems.len()
    }

    /// True when the shard ships no elements.
    pub fn is_empty(&self) -> bool {
        self.elems.is_empty()
    }

    /// Serialized size of this payload in wire bytes (the JSON document
    /// as framed by `dist::wire`) — what the shipping benchmarks and the
    /// payload-∝-shard tests measure.
    pub fn wire_bytes(&self) -> usize {
        serde_json::to_vec(&self.to_value()).map(|v| v.len()).unwrap_or(0)
    }

    /// Encode as a JSON value (embedded in `init_part` frames and in
    /// `ChildMsg.data`).  The schema is part of the wire protocol:
    /// changing it requires a `PROTOCOL_VERSION` bump.
    pub fn to_value(&self) -> Value {
        let data = match &self.data {
            PartitionData::Cover { universe, offsets, items, weights, self_cover, dominating } => {
                let mut v = json!({
                    "family": "cover",
                    "universe": universe,
                    "offsets": offsets,
                    "items": items,
                    "self_cover": self_cover,
                    "dominating": dominating,
                });
                if let Some(w) = weights {
                    v["weights"] =
                        Value::Array(w.iter().map(|(i, x)| json!([i, x])).collect());
                }
                v
            }
            PartitionData::Vectors { dim, flat } => json!({
                "family": "vectors",
                "dim": dim,
                "flat": flat.iter().map(|&x| Value::from(x)).collect::<Vec<_>>(),
            }),
            PartitionData::Facility { clients, columns } => json!({
                "family": "facility",
                "clients": clients,
                "columns": columns,
            }),
            PartitionData::Modular { weights } => json!({
                "family": "modular",
                "weights": weights,
            }),
        };
        json!({ "n_global": self.n_global, "elems": self.elems, "data": data })
    }

    /// Decode from a JSON value; errors are human-readable strings (the
    /// wire layer wraps them into `DistError::Backend`).
    pub fn from_value(v: &Value) -> Result<Self, String> {
        let n_global = field_u64(v, "n_global")? as usize;
        let elems: Vec<ElemId> = field_arr(v, "elems")?
            .iter()
            .map(|e| {
                e.as_u64()
                    .map(|x| x as ElemId)
                    .ok_or_else(|| "payload field 'elems': non-integer element".to_string())
            })
            .collect::<Result<_, _>>()?;
        let d = v.get("data").ok_or("payload missing field 'data'")?;
        let family = d
            .get("family")
            .and_then(Value::as_str)
            .ok_or("payload data missing 'family'")?;
        let data = match family {
            "cover" => PartitionData::Cover {
                universe: field_u64(d, "universe")? as usize,
                offsets: field_arr(d, "offsets")?
                    .iter()
                    .map(|x| x.as_u64().ok_or_else(|| "non-integer offset".to_string()))
                    .collect::<Result<_, _>>()?,
                items: field_arr(d, "items")?
                    .iter()
                    .map(|x| {
                        x.as_u64()
                            .map(|i| i as u32)
                            .ok_or_else(|| "non-integer item".to_string())
                    })
                    .collect::<Result<_, _>>()?,
                weights: match d.get("weights") {
                    None | Some(Value::Null) => None,
                    Some(w) => Some(
                        w.as_array()
                            .ok_or("payload 'weights' is not an array")?
                            .iter()
                            .map(|pair| {
                                let a = pair.as_array().filter(|a| a.len() == 2);
                                let a = a.ok_or("weight entry is not an [item, w] pair")?;
                                let item = a[0]
                                    .as_u64()
                                    .ok_or("weight item is not an integer")?
                                    as u32;
                                let w =
                                    a[1].as_f64().ok_or("weight value is not a number")?;
                                Ok::<(u32, f64), String>((item, w))
                            })
                            .collect::<Result<_, _>>()?,
                    ),
                },
                self_cover: field_bool(d, "self_cover")?,
                dominating: field_bool(d, "dominating")?,
            },
            "vectors" => PartitionData::Vectors {
                dim: field_u64(d, "dim")? as usize,
                flat: field_arr(d, "flat")?
                    .iter()
                    .map(|x| {
                        x.as_f64()
                            .map(|f| f as f32)
                            .ok_or_else(|| "non-numeric vector entry".to_string())
                    })
                    .collect::<Result<_, _>>()?,
            },
            "facility" => PartitionData::Facility {
                clients: field_u64(d, "clients")? as usize,
                columns: field_arr(d, "columns")?
                    .iter()
                    .map(|x| x.as_f64().ok_or_else(|| "non-numeric benefit".to_string()))
                    .collect::<Result<_, _>>()?,
            },
            "modular" => PartitionData::Modular {
                weights: field_arr(d, "weights")?
                    .iter()
                    .map(|x| x.as_f64().ok_or_else(|| "non-numeric weight".to_string()))
                    .collect::<Result<_, _>>()?,
            },
            other => return Err(format!("unknown payload family '{other}'")),
        };
        let payload = Self { n_global, elems, data };
        payload.validate()?;
        Ok(payload)
    }

    /// Structural consistency: id bounds, no duplicate elements, shape
    /// agreement between `elems` and the data arrays.  Both rebuild paths
    /// ([`PartitionOracle::from_payload`] and [`PartitionOracle::ingest`])
    /// run this, so a malformed frame fails the protocol instead of
    /// silently corrupting a shard.
    fn validate(&self) -> Result<(), String> {
        let n_local = self.elems.len();
        let mut seen = std::collections::HashSet::with_capacity(n_local);
        for &e in &self.elems {
            if (e as usize) >= self.n_global {
                return Err(format!(
                    "payload element {e} exceeds the global ground set ({})",
                    self.n_global
                ));
            }
            if !seen.insert(e) {
                return Err(format!("payload ships element {e} twice"));
            }
        }
        match &self.data {
            PartitionData::Cover { offsets, items, universe, weights, .. } => {
                if offsets.len() != n_local + 1 {
                    return Err(format!(
                        "cover payload: {} offsets for {n_local} elements",
                        offsets.len()
                    ));
                }
                if offsets.first().copied().unwrap_or(1) != 0
                    || offsets.last().copied().unwrap_or(0) as usize != items.len()
                    || offsets.windows(2).any(|w| w[0] > w[1])
                {
                    return Err("cover payload: malformed CSR offsets".into());
                }
                if items.iter().any(|&i| (i as usize) >= *universe) {
                    return Err("cover payload: item outside the universe".into());
                }
                if let Some(w) = weights {
                    // Every item a gain query can touch must have a
                    // shipped weight, or the rebuilt state would panic
                    // mid-scan instead of failing the handshake.
                    let known: std::collections::HashSet<u32> =
                        w.iter().map(|&(i, _)| i).collect();
                    if let Some(&i) = items.iter().find(|i| !known.contains(*i)) {
                        return Err(format!("cover payload: item {i} has no weight"));
                    }
                }
            }
            PartitionData::Vectors { dim, flat } => {
                if *dim == 0 || flat.len() != n_local * dim {
                    return Err(format!(
                        "vector payload: {} floats for {n_local} rows of dim {dim}",
                        flat.len()
                    ));
                }
            }
            PartitionData::Facility { clients, columns } => {
                if columns.len() != n_local * clients {
                    return Err(format!(
                        "facility payload: {} benefits for {n_local} columns of {clients} clients",
                        columns.len()
                    ));
                }
            }
            PartitionData::Modular { weights } => {
                if weights.len() != n_local {
                    return Err(format!(
                        "modular payload: {} weights for {n_local} elements",
                        weights.len()
                    ));
                }
            }
        }
        Ok(())
    }
}

// ---- binary wire codec (wire v5, content type 0x02) --------------------
//
// The JSON document above stays the debuggable encoding; this is the
// compact one.  A payload is a fixed 20-byte header, a 9-byte descriptor
// per section, then the sections back-to-back as raw little-endian
// slices — no intermediate tree on either side:
//
//   [0]      family   (1 = cover, 2 = vectors, 3 = facility, 4 = modular)
//   [1]      flags    (cover only: bit0 self_cover, bit1 dominating,
//                      bit2 weighted; must be 0 otherwise)
//   [2]      n_sections
//   [3]      reserved, must be 0
//   [4..12]  n_global  u64 LE
//   [12..20] meta      u64 LE (cover: universe; vectors: dim;
//                      facility: clients; modular: 0)
//   then per section: [byte_len u64 LE][width u8]
//
// Section 0 is always `elems`.  Integer sections (elems, cover row
// lengths, cover items) use the minimal width in {1, 2, 4, 8} that fits
// the section's largest value; cover CSR offsets travel as per-row
// *lengths* (reconstructed by prefix sum), which keeps them width-1 for
// realistic shards.  Float sections are fixed width (f32 = 4, f64 = 8,
// bit-exact via to_bits), and weighted-cover pairs use a 12-byte stride
// (u32 item + f64 bits).  A decoder must verify the declared section
// lengths sum exactly to the frame's payload length *before* allocating
// anything sized by them — that is the cap against hostile length
// fields.

const FAMILY_COVER: u8 = 1;
const FAMILY_VECTORS: u8 = 2;
const FAMILY_FACILITY: u8 = 3;
const FAMILY_MODULAR: u8 = 4;
const FLAG_SELF_COVER: u8 = 1;
const FLAG_DOMINATING: u8 = 2;
const FLAG_WEIGHTED: u8 = 4;
/// Fixed header bytes before the per-section descriptors.
const HEADER_FIXED: usize = 20;
/// Bytes per section descriptor (u64 length + u8 width).
const SECTION_DESC: usize = 9;
/// Width byte of a weighted-cover pair section (u32 item + f64 bits).
const WEIGHT_STRIDE: u8 = 12;

/// Minimal little-endian width in {1, 2, 4, 8} that holds `max`.
fn int_width(max: u64) -> u8 {
    if max < 1 << 8 {
        1
    } else if max < 1 << 16 {
        2
    } else if max < 1 << 32 {
        4
    } else {
        8
    }
}

fn push_ints(out: &mut Vec<u8>, vals: impl Iterator<Item = u64>, width: u8) {
    for v in vals {
        out.extend_from_slice(&v.to_le_bytes()[..width as usize]);
    }
}

fn decode_ints(bytes: &[u8], width: u8) -> Vec<u64> {
    bytes
        .chunks_exact(width as usize)
        .map(|c| {
            let mut v = [0u8; 8];
            v[..c.len()].copy_from_slice(c);
            u64::from_le_bytes(v)
        })
        .collect()
}

/// What a section's raw bytes decode to, per family and position.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SectionKind {
    Ints,
    F32s,
    F64s,
    Weights,
}

impl SectionKind {
    fn width_ok(self, width: u8) -> bool {
        match self {
            Self::Ints => matches!(width, 1 | 2 | 4 | 8),
            Self::F32s => width == 4,
            Self::F64s => width == 8,
            Self::Weights => width == WEIGHT_STRIDE,
        }
    }
}

/// The section layout a family declares (section 0, `elems`, included).
fn section_plan(family: u8, flags: u8) -> Result<Vec<SectionKind>, String> {
    match family {
        FAMILY_COVER => {
            if flags & !(FLAG_SELF_COVER | FLAG_DOMINATING | FLAG_WEIGHTED) != 0 {
                return Err(format!("binary payload: unknown cover flags {flags:#04x}"));
            }
            let mut plan = vec![SectionKind::Ints, SectionKind::Ints, SectionKind::Ints];
            if flags & FLAG_WEIGHTED != 0 {
                plan.push(SectionKind::Weights);
            }
            Ok(plan)
        }
        FAMILY_VECTORS | FAMILY_FACILITY | FAMILY_MODULAR => {
            if flags != 0 {
                return Err(format!(
                    "binary payload: family {family} carries no flags, got {flags:#04x}"
                ));
            }
            let second =
                if family == FAMILY_VECTORS { SectionKind::F32s } else { SectionKind::F64s };
            Ok(vec![SectionKind::Ints, second])
        }
        other => Err(format!("unknown binary payload family {other}")),
    }
}

/// One fully-decoded section, typed by its [`SectionKind`].
enum TypedSection {
    Ints(Vec<u64>),
    F32s(Vec<f32>),
    F64s(Vec<f64>),
    Weights(Vec<(u32, f64)>),
}

struct BinHeader {
    family: u8,
    flags: u8,
    n_global: u64,
    meta: u64,
    /// `(byte_len, width)` per section.
    sections: Vec<(usize, u8)>,
    kinds: Vec<SectionKind>,
}

/// Incremental decoder for the binary payload encoding: feed arriving
/// byte chunks in any sizes and each section is converted to its typed
/// form the moment its last byte lands, so decode work overlaps socket
/// reads instead of following them.  `new` takes the payload's declared
/// total byte length (from the already-capped frame prefix); nothing
/// sized by a declared *section* length is allocated until the section
/// table is proven to sum exactly to that total, so a hostile header
/// cannot force an over-allocation.
pub struct PartitionDecoder {
    expected: usize,
    fed: usize,
    header_buf: Vec<u8>,
    header: Option<BinHeader>,
    /// Raw bytes of the section currently filling.
    pending: Vec<u8>,
    done: Vec<TypedSection>,
    cur: usize,
}

impl PartitionDecoder {
    /// Start decoding a payload of exactly `expected` bytes.
    pub fn new(expected: usize) -> Self {
        Self {
            expected,
            fed: 0,
            header_buf: Vec::new(),
            header: None,
            pending: Vec::new(),
            done: Vec::new(),
            cur: 0,
        }
    }

    /// Number of sections whose bytes have fully arrived and been
    /// converted.  Monotone non-decreasing across `feed` calls.
    pub fn ready_sections(&self) -> usize {
        self.done.len()
    }

    /// Total section count, known once the header has arrived.
    pub fn total_sections(&self) -> Option<usize> {
        self.header.as_ref().map(|h| h.sections.len())
    }

    /// True once every declared byte has arrived.
    pub fn is_complete(&self) -> bool {
        match &self.header {
            Some(h) => self.cur == h.sections.len(),
            None => false,
        }
    }

    /// Absorb the next chunk of payload bytes.
    pub fn feed(&mut self, mut chunk: &[u8]) -> Result<(), String> {
        if self.fed + chunk.len() > self.expected {
            return Err(format!(
                "binary payload: fed {} bytes past the declared length {}",
                self.fed + chunk.len(),
                self.expected
            ));
        }
        self.fed += chunk.len();
        while !chunk.is_empty() {
            if self.header.is_none() {
                // The header's own length is only known once byte [2]
                // (n_sections) has arrived.
                let goal = if self.header_buf.len() < 3 {
                    3
                } else {
                    HEADER_FIXED + SECTION_DESC * self.header_buf[2] as usize
                };
                let take = (goal - self.header_buf.len()).min(chunk.len());
                self.header_buf.extend_from_slice(&chunk[..take]);
                chunk = &chunk[take..];
                if self.header_buf.len() >= 3 {
                    let full = HEADER_FIXED + SECTION_DESC * self.header_buf[2] as usize;
                    if self.header_buf.len() == full {
                        self.parse_header()?;
                        self.advance_empty();
                    }
                }
            } else {
                let Some(&(len, _)) = self.header.as_ref().and_then(|h| h.sections.get(self.cur))
                else {
                    return Err("binary payload: bytes past the last section".into());
                };
                if self.pending.is_empty() {
                    // Bounded by the sum check in parse_header.
                    self.pending.reserve_exact(len);
                }
                let take = (len - self.pending.len()).min(chunk.len());
                self.pending.extend_from_slice(&chunk[..take]);
                chunk = &chunk[take..];
                if self.pending.len() == len {
                    self.complete_section();
                    self.advance_empty();
                }
            }
        }
        Ok(())
    }

    /// Parse and validate the fully-buffered header.  Every check that
    /// gates allocation happens here, before any section buffer exists.
    fn parse_header(&mut self) -> Result<(), String> {
        let b = &self.header_buf;
        let (family, flags, n_sections, reserved) = (b[0], b[1], b[2] as usize, b[3]);
        if reserved != 0 {
            return Err(format!("binary payload: reserved header byte is {reserved}, not 0"));
        }
        let kinds = section_plan(family, flags)?;
        if n_sections != kinds.len() {
            return Err(format!(
                "binary payload: family {family} declares {n_sections} sections, expected {}",
                kinds.len()
            ));
        }
        let n_global = u64::from_le_bytes(b[4..12].try_into().unwrap());
        let meta = u64::from_le_bytes(b[12..20].try_into().unwrap());
        if family == FAMILY_MODULAR && meta != 0 {
            return Err(format!("binary payload: modular meta must be 0, got {meta}"));
        }
        let mut sections = Vec::with_capacity(n_sections);
        let mut declared = b.len();
        for (i, kind) in kinds.iter().enumerate() {
            let at = HEADER_FIXED + SECTION_DESC * i;
            let len = u64::from_le_bytes(b[at..at + 8].try_into().unwrap());
            let width = b[at + 8];
            if !kind.width_ok(width) {
                return Err(format!("binary payload: section {i} has invalid width {width}"));
            }
            if len % width as u64 != 0 {
                return Err(format!(
                    "binary payload: section {i} length {len} is not a multiple of width {width}"
                ));
            }
            let len = usize::try_from(len)
                .map_err(|_| format!("binary payload: section {i} length {len} overflows"))?;
            declared = declared
                .checked_add(len)
                .ok_or_else(|| "binary payload: section lengths overflow".to_string())?;
            sections.push((len, width));
        }
        // The hostile-length cap: the header must account for the frame's
        // payload bytes exactly, or nothing gets allocated.
        if declared != self.expected {
            return Err(format!(
                "binary payload: header declares {declared} bytes, frame carries {}",
                self.expected
            ));
        }
        self.header = Some(BinHeader { family, flags, n_global, meta, sections, kinds });
        Ok(())
    }

    /// Convert the just-finished section's raw bytes to its typed form.
    fn complete_section(&mut self) {
        let h = self.header.as_ref().expect("section completed before the header");
        let (_, width) = h.sections[self.cur];
        let bytes = std::mem::take(&mut self.pending);
        let typed = match h.kinds[self.cur] {
            SectionKind::Ints => TypedSection::Ints(decode_ints(&bytes, width)),
            SectionKind::F32s => TypedSection::F32s(
                bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
                    .collect(),
            ),
            SectionKind::F64s => TypedSection::F64s(
                bytes
                    .chunks_exact(8)
                    .map(|c| f64::from_bits(u64::from_le_bytes(c.try_into().unwrap())))
                    .collect(),
            ),
            SectionKind::Weights => TypedSection::Weights(
                bytes
                    .chunks_exact(WEIGHT_STRIDE as usize)
                    .map(|c| {
                        (
                            u32::from_le_bytes(c[..4].try_into().unwrap()),
                            f64::from_bits(u64::from_le_bytes(c[4..].try_into().unwrap())),
                        )
                    })
                    .collect(),
            ),
        };
        self.done.push(typed);
        self.cur += 1;
    }

    /// Zero-length sections complete the moment they are reached —
    /// including a run of them at the very end of the payload, where no
    /// further `feed` bytes will arrive to drive the loop.
    fn advance_empty(&mut self) {
        while let Some(&(0, _)) = self.header.as_ref().and_then(|h| h.sections.get(self.cur)) {
            self.complete_section();
        }
    }

    /// Assemble the payload.  Errors if any declared byte is missing, if
    /// a value does not fit its field, or if the payload fails the same
    /// [`PartitionPayload::validate`] the JSON path runs.
    pub fn finish(self) -> Result<PartitionPayload, String> {
        let Some(h) = self.header else {
            return Err(format!(
                "binary payload truncated: {} of {} bytes arrived before the header completed",
                self.fed, self.expected
            ));
        };
        if self.cur < h.sections.len() {
            return Err(format!(
                "binary payload truncated in section {} of {} ({} of {} bytes arrived)",
                self.cur + 1,
                h.sections.len(),
                self.fed,
                self.expected
            ));
        }
        let mut done = self.done.into_iter();
        let elems = match done.next() {
            Some(TypedSection::Ints(vals)) => vals
                .into_iter()
                .map(|v| {
                    ElemId::try_from(v)
                        .map_err(|_| format!("binary payload: element id {v} exceeds u32"))
                })
                .collect::<Result<Vec<ElemId>, String>>()?,
            _ => unreachable!("section 0 is always integer elems"),
        };
        let data = match h.family {
            FAMILY_COVER => {
                let Some(TypedSection::Ints(row_lens)) = done.next() else { unreachable!() };
                let Some(TypedSection::Ints(raw_items)) = done.next() else { unreachable!() };
                let mut offsets = Vec::with_capacity(row_lens.len() + 1);
                let mut acc = 0u64;
                offsets.push(0);
                for len in row_lens {
                    acc = acc
                        .checked_add(len)
                        .ok_or_else(|| "binary payload: row lengths overflow".to_string())?;
                    offsets.push(acc);
                }
                let items = raw_items
                    .into_iter()
                    .map(|v| {
                        u32::try_from(v)
                            .map_err(|_| format!("binary payload: item {v} exceeds u32"))
                    })
                    .collect::<Result<Vec<u32>, String>>()?;
                let weights = match done.next() {
                    None => None,
                    Some(TypedSection::Weights(w)) => Some(w),
                    Some(_) => unreachable!("cover section 3 is always weights"),
                };
                PartitionData::Cover {
                    universe: h.meta as usize,
                    offsets,
                    items,
                    weights,
                    self_cover: h.flags & FLAG_SELF_COVER != 0,
                    dominating: h.flags & FLAG_DOMINATING != 0,
                }
            }
            FAMILY_VECTORS => {
                let Some(TypedSection::F32s(flat)) = done.next() else { unreachable!() };
                PartitionData::Vectors { dim: h.meta as usize, flat }
            }
            FAMILY_FACILITY => {
                let Some(TypedSection::F64s(columns)) = done.next() else { unreachable!() };
                PartitionData::Facility { clients: h.meta as usize, columns }
            }
            FAMILY_MODULAR => {
                let Some(TypedSection::F64s(weights)) = done.next() else { unreachable!() };
                PartitionData::Modular { weights }
            }
            _ => unreachable!("parse_header admits only known families"),
        };
        let n_global = usize::try_from(h.n_global)
            .map_err(|_| format!("binary payload: n_global {} overflows", h.n_global))?;
        let payload = PartitionPayload { n_global, elems, data };
        payload.validate()?;
        Ok(payload)
    }
}

impl PartitionPayload {
    /// `(family, flags, meta)` header fields of the binary encoding.
    fn binary_family(&self) -> (u8, u8, u64) {
        match &self.data {
            PartitionData::Cover { universe, weights, self_cover, dominating, .. } => (
                FAMILY_COVER,
                (*self_cover as u8) * FLAG_SELF_COVER
                    | (*dominating as u8) * FLAG_DOMINATING
                    | (weights.is_some() as u8) * FLAG_WEIGHTED,
                *universe as u64,
            ),
            PartitionData::Vectors { dim, .. } => (FAMILY_VECTORS, 0, *dim as u64),
            PartitionData::Facility { clients, .. } => (FAMILY_FACILITY, 0, *clients as u64),
            PartitionData::Modular { .. } => (FAMILY_MODULAR, 0, 0),
        }
    }

    /// The `(byte_len, width)` section table, plus the cover per-row
    /// lengths (computed once; the encoder needs them twice).
    fn binary_section_table(&self) -> (Vec<(usize, u8)>, Vec<u64>) {
        let ew = int_width(self.elems.iter().map(|&e| e as u64).max().unwrap_or(0));
        let mut sections = vec![(self.elems.len() * ew as usize, ew)];
        let mut row_lens = Vec::new();
        match &self.data {
            PartitionData::Cover { offsets, items, weights, .. } => {
                row_lens = offsets.windows(2).map(|w| w[1] - w[0]).collect();
                let rw = int_width(row_lens.iter().copied().max().unwrap_or(0));
                sections.push((row_lens.len() * rw as usize, rw));
                let iw = int_width(items.iter().map(|&i| i as u64).max().unwrap_or(0));
                sections.push((items.len() * iw as usize, iw));
                if let Some(w) = weights {
                    sections.push((w.len() * WEIGHT_STRIDE as usize, WEIGHT_STRIDE));
                }
            }
            PartitionData::Vectors { flat, .. } => sections.push((flat.len() * 4, 4)),
            PartitionData::Facility { columns, .. } => sections.push((columns.len() * 8, 8)),
            PartitionData::Modular { weights } => sections.push((weights.len() * 8, 8)),
        }
        (sections, row_lens)
    }

    /// Exact byte length of [`PartitionPayload::encode_binary`]'s output,
    /// without encoding — envelope writers size their frames with this.
    pub fn binary_len(&self) -> usize {
        let (sections, _) = self.binary_section_table();
        HEADER_FIXED
            + SECTION_DESC * sections.len()
            + sections.iter().map(|&(len, _)| len).sum::<usize>()
    }

    /// Append the binary encoding (header, section table, raw sections)
    /// to `out`, section by section — no intermediate tree.
    pub fn encode_binary(&self, out: &mut Vec<u8>) {
        let (family, flags, meta) = self.binary_family();
        let (sections, row_lens) = self.binary_section_table();
        out.reserve(
            HEADER_FIXED
                + SECTION_DESC * sections.len()
                + sections.iter().map(|&(len, _)| len).sum::<usize>(),
        );
        out.extend_from_slice(&[family, flags, sections.len() as u8, 0]);
        out.extend_from_slice(&(self.n_global as u64).to_le_bytes());
        out.extend_from_slice(&meta.to_le_bytes());
        for &(len, width) in &sections {
            out.extend_from_slice(&(len as u64).to_le_bytes());
            out.push(width);
        }
        push_ints(out, self.elems.iter().map(|&e| e as u64), sections[0].1);
        match &self.data {
            PartitionData::Cover { items, weights, .. } => {
                push_ints(out, row_lens.iter().copied(), sections[1].1);
                push_ints(out, items.iter().map(|&i| i as u64), sections[2].1);
                if let Some(w) = weights {
                    for &(item, x) in w {
                        out.extend_from_slice(&item.to_le_bytes());
                        out.extend_from_slice(&x.to_bits().to_le_bytes());
                    }
                }
            }
            PartitionData::Vectors { flat, .. } => {
                for &x in flat {
                    out.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
            PartitionData::Facility { columns, .. } => {
                for &x in columns {
                    out.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
            PartitionData::Modular { weights } => {
                for &x in weights {
                    out.extend_from_slice(&x.to_bits().to_le_bytes());
                }
            }
        }
    }

    /// One-shot decode: a [`PartitionDecoder`] fed the whole buffer at
    /// once, which also guarantees streaming and one-shot decodes agree.
    pub fn decode_binary(bytes: &[u8]) -> Result<Self, String> {
        let mut dec = PartitionDecoder::new(bytes.len());
        dec.feed(bytes)?;
        dec.finish()
    }
}

// ---- live-dataset deltas (wire v6) --------------------------------------

/// A serde-stable diff against a partitioned dataset: global-id inserts
/// (with their per-family data rows, packaged exactly like a shard) plus
/// global-id deletes.  One delta advances the dataset **epoch** by one;
/// the coordinator applies it to its full-view oracle and fans per-machine
/// sub-deltas to a resident fleet (`delta` frames, wire v6) so workers
/// update shards in place instead of re-shipping O(n/m) payloads.
#[derive(Clone, Debug, PartialEq)]
pub struct PartitionDelta {
    /// Global ground-set size *after* this delta.  Inserts may grow the
    /// id space; it never shrinks (deleted ids simply leave every shard).
    pub n_global: usize,
    /// Inserted elements and their data rows.  `insert.n_global` must
    /// equal the post-delta [`PartitionDelta::n_global`].
    pub insert: PartitionPayload,
    /// Deleted global element ids.
    pub delete: Vec<ElemId>,
}

impl PartitionDelta {
    /// Number of inserted plus deleted elements.
    pub fn len(&self) -> usize {
        self.insert.len() + self.delete.len()
    }

    /// True when the delta changes nothing.
    pub fn is_empty(&self) -> bool {
        self.insert.is_empty() && self.delete.is_empty()
    }

    /// Structural consistency: the insert payload validates against the
    /// post-delta ground set, deletes are in range and unique, and no id
    /// is both inserted and deleted (a replace is delete-old + insert-new
    /// under a fresh id).
    pub fn validate(&self) -> Result<(), String> {
        if self.insert.n_global != self.n_global {
            return Err(format!(
                "delta: insert payload describes a ground set of {} elements, \
                 delta declares {}",
                self.insert.n_global, self.n_global
            ));
        }
        self.insert.validate()?;
        let mut seen = std::collections::HashSet::with_capacity(self.delete.len());
        for &e in &self.delete {
            if (e as usize) >= self.n_global {
                return Err(format!(
                    "delta deletes element {e} outside the ground set ({})",
                    self.n_global
                ));
            }
            if !seen.insert(e) {
                return Err(format!("delta deletes element {e} twice"));
            }
        }
        if let Some(&e) = self.insert.elems.iter().find(|e| seen.contains(e)) {
            return Err(format!("delta both inserts and deletes element {e}"));
        }
        Ok(())
    }

    /// Encode as a JSON value (embedded in `delta` frames; part of the
    /// wire protocol like [`PartitionPayload::to_value`]).
    pub fn to_value(&self) -> Value {
        json!({
            "n_global": self.n_global,
            "insert": self.insert.to_value(),
            "delete": self.delete,
        })
    }

    /// Decode from a JSON value; validates like the payload path.
    pub fn from_value(v: &Value) -> Result<Self, String> {
        let n_global = field_u64(v, "n_global")? as usize;
        let insert = PartitionPayload::from_value(
            v.get("insert").ok_or("delta missing field 'insert'")?,
        )?;
        let delete: Vec<ElemId> = field_arr(v, "delete")?
            .iter()
            .map(|e| {
                e.as_u64()
                    .map(|x| x as ElemId)
                    .ok_or_else(|| "delta field 'delete': non-integer element".to_string())
            })
            .collect::<Result<_, _>>()?;
        let delta = Self { n_global, insert, delete };
        delta.validate()?;
        Ok(delta)
    }

    /// Exact byte length of [`PartitionDelta::encode_binary`]'s output.
    pub fn binary_len(&self) -> usize {
        8 + 4 + 4 * self.delete.len() + self.insert.binary_len()
    }

    /// Append the binary encoding: `[n_global u64 LE][n_delete u32 LE]`
    /// `[delete ids u32 LE …]` then the insert payload's section encoding.
    pub fn encode_binary(&self, out: &mut Vec<u8>) {
        out.reserve(self.binary_len());
        out.extend_from_slice(&(self.n_global as u64).to_le_bytes());
        out.extend_from_slice(&(self.delete.len() as u32).to_le_bytes());
        for &e in &self.delete {
            out.extend_from_slice(&e.to_le_bytes());
        }
        self.insert.encode_binary(out);
    }

    /// Decode the binary encoding and validate.
    pub fn decode_binary(bytes: &[u8]) -> Result<Self, String> {
        if bytes.len() < 12 {
            return Err("binary delta: truncated header".into());
        }
        let n_global = u64::from_le_bytes(bytes[..8].try_into().unwrap());
        let n_global = usize::try_from(n_global)
            .map_err(|_| format!("binary delta: n_global {n_global} overflows"))?;
        let n_delete = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let end = 12usize
            .checked_add(n_delete.checked_mul(4).ok_or("binary delta: delete count overflows")?)
            .ok_or("binary delta: delete count overflows")?;
        if bytes.len() < end {
            return Err(format!(
                "binary delta: {n_delete} deletes declared, frame holds {} bytes",
                bytes.len()
            ));
        }
        let delete: Vec<ElemId> = bytes[12..end]
            .chunks_exact(4)
            .map(|c| ElemId::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let insert = PartitionPayload::decode_binary(&bytes[end..])?;
        let delta = Self { n_global, insert, delete };
        delta.validate()?;
        Ok(delta)
    }
}

fn field_u64(v: &Value, key: &str) -> Result<u64, String> {
    v.get(key)
        .and_then(Value::as_u64)
        .ok_or_else(|| format!("payload field '{key}' missing or not a u64"))
}

fn field_bool(v: &Value, key: &str) -> Result<bool, String> {
    v.get(key)
        .and_then(Value::as_bool)
        .ok_or_else(|| format!("payload field '{key}' missing or not a bool"))
}

fn field_arr<'a>(v: &'a Value, key: &str) -> Result<&'a [Value], String> {
    v.get(key)
        .and_then(Value::as_array)
        .map(|a| a.as_slice())
        .ok_or_else(|| format!("payload field '{key}' missing or not an array"))
}

/// Slice the `(item, weight)` pairs a weighted cover shard must carry:
/// the sorted, deduplicated items present in `items`, each with its
/// weight.  Shared by the coordinator-side [`super::WeightedCover`]
/// extraction and the worker-side facade re-extraction — the two must
/// emit identical payloads or re-shipped solutions would not round-trip.
pub(crate) fn slice_weights(
    items: &[u32],
    weight_of: impl Fn(u32) -> f64,
) -> Vec<(u32, f64)> {
    let mut present: Vec<u32> = items.to_vec();
    present.sort_unstable();
    present.dedup();
    present.into_iter().map(|i| (i, weight_of(i))).collect()
}

/// The extraction half of partition shipping, implemented by every CPU
/// oracle.  Reached from a `dyn Oracle` through
/// [`Oracle::partitionable`]; oracles that cannot slice their dataset
/// (the PJRT-backed ones, whose data lives in AOT device buffers) simply
/// keep the default `None` and fall back to spec shipping.
pub trait Partitionable {
    /// Slice a serde-stable shard holding exactly `elems` (global ids),
    /// renumbered into the shard-local dense id space.
    fn extract_partition(&self, elems: &[ElemId]) -> PartitionPayload;

    /// True when this objective evaluates against the whole dataset
    /// unless restricted to a view — under partition shipping such an
    /// objective is exact only with machine-local evaluation
    /// (`local_view`, the §6.4 k-medoid scheme; Mirzasoleiman et al.,
    /// Thm 10 justifies the restriction).
    fn needs_local_view(&self) -> bool {
        false
    }
}

// ---- the worker-side rebuild: a global-id facade over shard data -------

/// Per-family shard storage inside a [`PartitionOracle`], renumbered to
/// local dense ids.
enum LocalData {
    Cover {
        offsets: Vec<u64>,
        items: Vec<u32>,
        universe: usize,
        weights: Option<HashMap<u32, f64>>,
        self_cover: bool,
        dominating: bool,
    },
    /// Master copy of the rows plus the rebuilt oracle (replaced after
    /// every ingest; norms re-derive deterministically from the rows).
    Medoid { dim: usize, flat: Vec<f32>, oracle: super::KMedoid },
    Facility { clients: usize, columns: Vec<f64> },
    Modular { weights: Vec<f64> },
}

/// An [`Oracle`] over a worker's shard.
///
/// Data is stored renumbered (local dense ids `0..len_local`), but the
/// facade speaks **global** ids: `n()` is the global ground-set size and
/// every state call translates candidate/view ids through the internal
/// map.  A gain query for an element outside the shard is a coordinator
/// bug (the protocol ships every element a machine will ever evaluate)
/// and panics with a descriptive message rather than returning a wrong
/// number.
pub struct PartitionOracle {
    n_global: usize,
    to_global: Vec<ElemId>,
    to_local: HashMap<ElemId, u32>,
    data: LocalData,
}

impl PartitionOracle {
    /// Rebuild from a shipped payload.
    pub fn from_payload(payload: &PartitionPayload) -> Result<Self, String> {
        payload.validate()?;
        let mut to_local = HashMap::with_capacity(payload.elems.len());
        for (local, &global) in payload.elems.iter().enumerate() {
            if to_local.insert(global, local as u32).is_some() {
                return Err(format!("payload ships element {global} twice"));
            }
        }
        let data = match &payload.data {
            PartitionData::Cover { universe, offsets, items, weights, self_cover, dominating } => {
                LocalData::Cover {
                    offsets: offsets.clone(),
                    items: items.clone(),
                    universe: *universe,
                    weights: weights.as_ref().map(|w| w.iter().copied().collect()),
                    self_cover: *self_cover,
                    dominating: *dominating,
                }
            }
            PartitionData::Vectors { dim, flat } => LocalData::Medoid {
                dim: *dim,
                flat: flat.clone(),
                oracle: super::KMedoid::new(Arc::new(
                    crate::data::vectors::VectorSet::from_flat(flat.clone(), *dim)
                        .map_err(|e| e.to_string())?,
                )),
            },
            PartitionData::Facility { clients, columns } => {
                LocalData::Facility { clients: *clients, columns: columns.clone() }
            }
            PartitionData::Modular { weights } => {
                LocalData::Modular { weights: weights.clone() }
            }
        };
        Ok(Self { n_global: payload.n_global, to_global: payload.elems.clone(), to_local, data })
    }

    /// Number of elements currently held (initial shard + everything
    /// ingested since).
    pub fn len_local(&self) -> usize {
        self.to_global.len()
    }

    /// Whether the shard currently holds element `e` (global id) — the
    /// worker session pre-validates incoming partitions against this so a
    /// coordinator bug surfaces as a protocol `Fail`, not a worker panic.
    pub fn holds(&self, e: ElemId) -> bool {
        self.to_local.contains_key(&e)
    }

    /// Global ids currently held, in shard order (initial shard plus every
    /// ingest, compacted after deltas) — the survivor list live-dataset
    /// coordinators replay partitions against.
    pub fn held(&self) -> &[ElemId] {
        &self.to_global
    }

    /// Whether this facade's objective is exact only under machine-local
    /// evaluation views (see [`Partitionable::needs_local_view`]).
    pub fn needs_local_view(&self) -> bool {
        matches!(self.data, LocalData::Medoid { .. })
    }

    /// Absorb another shard (a shipped child solution's data): elements
    /// already held are skipped, new ones are appended to the local dense
    /// ground set.
    pub fn ingest(&mut self, payload: &PartitionPayload) -> Result<(), String> {
        payload.validate()?;
        if payload.n_global != self.n_global {
            return Err(format!(
                "ingest: payload describes a ground set of {} elements, this shard holds {}",
                payload.n_global, self.n_global
            ));
        }
        let fresh: Vec<usize> = payload
            .elems
            .iter()
            .enumerate()
            .filter(|(_, g)| !self.to_local.contains_key(g))
            .map(|(i, _)| i)
            .collect();
        match (&mut self.data, &payload.data) {
            (
                LocalData::Cover { offsets, items, universe, weights, self_cover, dominating },
                PartitionData::Cover {
                    universe: u2,
                    offsets: o2,
                    items: i2,
                    weights: w2,
                    self_cover: s2,
                    dominating: d2,
                },
            ) => {
                if universe != u2 {
                    return Err(format!(
                        "ingest: item universe mismatch ({universe} vs {u2})"
                    ));
                }
                // Weight presence and the domination flags are part of the
                // objective's identity: a mismatch means the peer rebuilt a
                // *different* function, and absorbing its data would defer
                // the failure to a mid-scan panic instead of a protocol
                // Fail here.
                if weights.is_some() != w2.is_some()
                    || self_cover != s2
                    || dominating != d2
                {
                    return Err(
                        "ingest: cover payload describes a different objective \
                         (weights / self-cover / domination flags disagree)"
                            .into(),
                    );
                }
                if let (Some(w), Some(incoming)) = (weights.as_mut(), w2.as_ref()) {
                    for &(item, x) in incoming {
                        w.insert(item, x);
                    }
                }
                for &i in &fresh {
                    items.extend_from_slice(
                        &i2[o2[i] as usize..o2[i + 1] as usize],
                    );
                    offsets.push(items.len() as u64);
                }
            }
            (
                LocalData::Medoid { dim, flat, oracle },
                PartitionData::Vectors { dim: d2, flat: f2 },
            ) => {
                if dim != d2 {
                    return Err(format!("ingest: vector dim mismatch ({dim} vs {d2})"));
                }
                for &i in &fresh {
                    flat.extend_from_slice(&f2[i * *dim..(i + 1) * *dim]);
                }
                if !fresh.is_empty() {
                    *oracle = super::KMedoid::new(Arc::new(
                        crate::data::vectors::VectorSet::from_flat(flat.clone(), *dim)
                            .map_err(|e| e.to_string())?,
                    ));
                }
            }
            (
                LocalData::Facility { clients, columns },
                PartitionData::Facility { clients: c2, columns: x2 },
            ) => {
                if clients != c2 {
                    return Err(format!(
                        "ingest: client-count mismatch ({clients} vs {c2})"
                    ));
                }
                for &i in &fresh {
                    columns.extend_from_slice(&x2[i * *clients..(i + 1) * *clients]);
                }
            }
            (LocalData::Modular { weights }, PartitionData::Modular { weights: w2 }) => {
                for &i in &fresh {
                    weights.push(w2[i]);
                }
            }
            _ => return Err("ingest: payload family does not match this shard".into()),
        }
        for i in fresh {
            let g = payload.elems[i];
            self.to_local.insert(g, self.to_global.len() as u32);
            self.to_global.push(g);
        }
        Ok(())
    }

    /// Apply a live-dataset diff in place: deleted elements leave the
    /// shard, inserted elements append after the survivors, and the
    /// global ground set grows to `delta.n_global`.
    ///
    /// The shard **compacts** — deleted rows are physically removed and
    /// local ids renumbered — so an incrementally-updated oracle is
    /// structurally identical (same rows, same local order, same
    /// `elem_bytes` accounting) to one cold-built from the post-delta
    /// dataset with the same element order.  That structural identity is
    /// what makes incremental re-solves bit-identical to from-scratch
    /// runs.
    ///
    /// Deletes of elements this shard does not hold are skipped (another
    /// machine owns them); inserts must be fresh here — on a worker the
    /// coordinator's per-machine sub-delta guarantees it, and on the
    /// coordinator's full view a clash means the delta re-inserts a live
    /// id, which is refused.
    pub fn apply_delta(&mut self, delta: &PartitionDelta) -> Result<(), String> {
        delta.validate()?;
        if delta.n_global < self.n_global {
            return Err(format!(
                "delta shrinks the ground set ({} -> {}); deleted ids leave \
                 shards but the id space never contracts",
                self.n_global, delta.n_global
            ));
        }
        if let Some(&e) = delta.insert.elems.iter().find(|&&e| self.holds(e)) {
            return Err(format!("delta inserts element {e}, which is already held"));
        }
        let dels: std::collections::HashSet<ElemId> = delta.delete.iter().copied().collect();
        let survivors: Vec<ElemId> =
            self.to_global.iter().copied().filter(|g| !dels.contains(g)).collect();
        // Rebuild compacted: re-slice the survivors from the held shard,
        // widen the ground set, then absorb the inserts through the same
        // ingest path child solutions use.
        let mut base = self.extract(&survivors)?;
        base.n_global = delta.n_global;
        let mut rebuilt = Self::from_payload(&base)?;
        // Ingest even when empty: the family / universe / dim / client
        // checks still run, so a mismatched delta fails the protocol here.
        rebuilt.ingest(&delta.insert)?;
        *self = rebuilt;
        Ok(())
    }

    /// Extract a payload for `elems` (global ids) from the held shard —
    /// how a worker packages its solution's data for shipping to the
    /// parent.  Every element must be held locally.
    pub fn extract(&self, elems: &[ElemId]) -> Result<PartitionPayload, String> {
        let locals: Vec<u32> = elems
            .iter()
            .map(|e| {
                self.to_local.get(e).copied().ok_or_else(|| {
                    format!("extract: element {e} is not in this worker's shard")
                })
            })
            .collect::<Result<_, _>>()?;
        let data = match &self.data {
            LocalData::Cover { offsets, items, universe, weights, self_cover, dominating } => {
                let mut o = Vec::with_capacity(locals.len() + 1);
                o.push(0u64);
                let mut out_items = Vec::new();
                for &l in &locals {
                    out_items.extend_from_slice(
                        &items[offsets[l as usize] as usize..offsets[l as usize + 1] as usize],
                    );
                    o.push(out_items.len() as u64);
                }
                let w = weights.as_ref().map(|w| slice_weights(&out_items, |i| w[&i]));
                PartitionData::Cover {
                    universe: *universe,
                    offsets: o,
                    items: out_items,
                    weights: w,
                    self_cover: *self_cover,
                    dominating: *dominating,
                }
            }
            LocalData::Medoid { dim, flat, .. } => {
                let mut out = Vec::with_capacity(locals.len() * dim);
                for &l in &locals {
                    out.extend_from_slice(&flat[l as usize * dim..(l as usize + 1) * dim]);
                }
                PartitionData::Vectors { dim: *dim, flat: out }
            }
            LocalData::Facility { clients, columns } => {
                let mut out = Vec::with_capacity(locals.len() * clients);
                for &l in &locals {
                    out.extend_from_slice(
                        &columns[l as usize * clients..(l as usize + 1) * clients],
                    );
                }
                PartitionData::Facility { clients: *clients, columns: out }
            }
            LocalData::Modular { weights } => PartitionData::Modular {
                weights: locals.iter().map(|&l| weights[l as usize]).collect(),
            },
        };
        Ok(PartitionPayload { n_global: self.n_global, elems: elems.to_vec(), data })
    }

    #[inline]
    fn local(&self, e: ElemId) -> u32 {
        match self.to_local.get(&e) {
            Some(&l) => l,
            None => panic!(
                "element {e} is not in this worker's shard of {} elements — \
                 the coordinator failed to ship data the node program needs \
                 (partition-shipping protocol bug)",
                self.to_global.len()
            ),
        }
    }

    fn cover_set(&self, l: u32) -> &[u32] {
        match &self.data {
            LocalData::Cover { offsets, items, .. } => {
                &items[offsets[l as usize] as usize..offsets[l as usize + 1] as usize]
            }
            _ => unreachable!("cover_set on a non-cover shard"),
        }
    }
}

impl Oracle for PartitionOracle {
    fn n(&self) -> usize {
        self.n_global
    }

    fn name(&self) -> &'static str {
        match &self.data {
            LocalData::Cover { dominating: true, .. } => "k-dominating-set",
            LocalData::Cover { weights: Some(_), .. } => "weighted-cover",
            LocalData::Cover { .. } => "k-cover",
            LocalData::Medoid { .. } => "k-medoid",
            LocalData::Facility { .. } => "facility-location",
            LocalData::Modular { .. } => "modular",
        }
    }

    fn new_state<'a>(&'a self, view: Option<&[ElemId]>) -> Box<dyn GainState + 'a> {
        match &self.data {
            LocalData::Cover { universe, weights, self_cover, .. } => {
                // Coverage ignores the view (items live in a global
                // universe); gain math mirrors KCover / WeightedCover /
                // KDominatingSet states exactly.
                Box::new(CoverFacadeState {
                    oracle: self,
                    weights: weights.as_ref(),
                    self_cover: *self_cover,
                    covered: BitSet::new(*universe),
                    covered_count: 0,
                    value: 0.0,
                    solution: Vec::new(),
                })
            }
            LocalData::Medoid { oracle, .. } => {
                let view = view.unwrap_or_else(|| {
                    panic!(
                        "the k-medoid partition oracle needs an explicit evaluation \
                         view (run with local_view; a partition-shipped worker \
                         cannot evaluate against the full dataset)"
                    )
                });
                let local_view: Vec<ElemId> =
                    view.iter().map(|&e| self.local(e) as ElemId).collect();
                Box::new(TranslatedState {
                    oracle: self,
                    inner: oracle.new_state(Some(&local_view)),
                    solution: Vec::new(),
                })
            }
            LocalData::Facility { clients, columns } => Box::new(FacilityFacadeState {
                oracle: self,
                clients: *clients,
                columns,
                best: vec![0.0; *clients],
                solution: Vec::new(),
            }),
            LocalData::Modular { weights } => Box::new(ModularFacadeState {
                oracle: self,
                weights,
                value: 0.0,
                solution: Vec::new(),
            }),
        }
    }

    fn elem_bytes(&self, e: ElemId) -> usize {
        let l = self.local(e);
        match &self.data {
            // Identical formulas to ItemsetCollection::elem_bytes /
            // CsrGraph::elem_bytes — the memory-charge sequences must
            // match the thread backend byte for byte.
            LocalData::Cover { offsets, .. } => {
                8 + 4 * (offsets[l as usize + 1] - offsets[l as usize]) as usize
            }
            LocalData::Medoid { dim, .. } => 8 + 4 * dim,
            LocalData::Facility { clients, .. } => 8 + 8 * clients,
            LocalData::Modular { .. } => 16,
        }
    }

    fn partitionable(&self) -> Option<&dyn Partitionable> {
        Some(self)
    }
}

impl Partitionable for PartitionOracle {
    fn extract_partition(&self, elems: &[ElemId]) -> PartitionPayload {
        // Facade extraction is re-slicing the held shard; unknown
        // elements are a protocol bug, reported like a gain on one.
        self.extract(elems).unwrap_or_else(|e| panic!("{e}"))
    }

    fn needs_local_view(&self) -> bool {
        self.needs_local_view()
    }
}

/// Coverage-family facade state: the union of KCover / WeightedCover /
/// KDominatingSet state machines, keyed on global candidate ids.
struct CoverFacadeState<'a> {
    oracle: &'a PartitionOracle,
    weights: Option<&'a HashMap<u32, f64>>,
    self_cover: bool,
    covered: BitSet,
    covered_count: usize,
    value: f64,
    solution: Vec<ElemId>,
}

impl GainState for CoverFacadeState<'_> {
    fn value(&self) -> f64 {
        match self.weights {
            Some(_) => self.value,
            None => self.covered_count as f64,
        }
    }

    #[inline]
    fn gain(&self, e: ElemId) -> f64 {
        let set = self.oracle.cover_set(self.oracle.local(e));
        match self.weights {
            Some(w) => set
                .iter()
                .filter(|&&i| !self.covered.contains(i as usize))
                .map(|&i| w[&i])
                .sum(),
            None => {
                let mut g = self.covered.union_gain_sparse(set);
                if self.self_cover {
                    g += !self.covered.contains(e as usize) as usize;
                }
                g as f64
            }
        }
    }

    fn commit(&mut self, e: ElemId) {
        let l = self.oracle.local(e);
        match self.weights {
            Some(w) => {
                for &i in self.oracle.cover_set(l) {
                    if self.covered.insert(i as usize) {
                        self.value += w[&i];
                    }
                }
            }
            None => {
                self.covered_count += self.covered.insert_sparse(self.oracle.cover_set(l));
                if self.self_cover {
                    self.covered_count += self.covered.insert(e as usize) as usize;
                }
            }
        }
        self.solution.push(e);
    }

    fn solution(&self) -> &[ElemId] {
        &self.solution
    }

    fn call_cost(&self, e: ElemId) -> u64 {
        self.oracle.cover_set(self.oracle.local(e)).len() as u64
    }
}

/// k-medoid facade state: candidates and the view arrive as global ids,
/// the inner tiled-kernel state runs on shard-local ids.
struct TranslatedState<'a> {
    oracle: &'a PartitionOracle,
    inner: Box<dyn GainState + 'a>,
    solution: Vec<ElemId>,
}

impl GainState for TranslatedState<'_> {
    fn value(&self) -> f64 {
        self.inner.value()
    }

    fn gain(&self, e: ElemId) -> f64 {
        self.inner.gain(self.oracle.local(e) as ElemId)
    }

    fn gain_batch(&self, es: &[ElemId], out: &mut Vec<f64>) {
        let locals: Vec<ElemId> =
            es.iter().map(|&e| self.oracle.local(e) as ElemId).collect();
        self.inner.gain_batch(&locals, out);
    }

    fn commit(&mut self, e: ElemId) {
        self.inner.commit(self.oracle.local(e) as ElemId);
        self.solution.push(e);
    }

    fn solution(&self) -> &[ElemId] {
        &self.solution
    }

    fn call_cost(&self, e: ElemId) -> u64 {
        self.inner.call_cost(self.oracle.local(e) as ElemId)
    }

    fn parallel_scan(&self) -> bool {
        self.inner.parallel_scan()
    }
}

/// Facility-location facade state (mirrors `facility::FacState`).
struct FacilityFacadeState<'a> {
    oracle: &'a PartitionOracle,
    clients: usize,
    columns: &'a [f64],
    best: Vec<f64>,
    solution: Vec<ElemId>,
}

impl FacilityFacadeState<'_> {
    #[inline]
    fn column(&self, e: ElemId) -> &[f64] {
        let l = self.oracle.local(e) as usize;
        &self.columns[l * self.clients..(l + 1) * self.clients]
    }
}

impl GainState for FacilityFacadeState<'_> {
    fn value(&self) -> f64 {
        self.best.iter().sum()
    }

    fn gain(&self, e: ElemId) -> f64 {
        let col = self.column(e);
        let mut acc = 0.0;
        for (c, &b) in self.best.iter().enumerate() {
            if col[c] > b {
                acc += col[c] - b;
            }
        }
        acc
    }

    fn commit(&mut self, e: ElemId) {
        let l = self.oracle.local(e) as usize;
        for (c, b) in self.best.iter_mut().enumerate() {
            let w = self.columns[l * self.clients + c];
            if w > *b {
                *b = w;
            }
        }
        self.solution.push(e);
    }

    fn solution(&self) -> &[ElemId] {
        &self.solution
    }

    fn call_cost(&self, _e: ElemId) -> u64 {
        self.clients as u64
    }
}

/// Modular facade state (mirrors `modular::ModularState`).
struct ModularFacadeState<'a> {
    oracle: &'a PartitionOracle,
    weights: &'a [f64],
    value: f64,
    solution: Vec<ElemId>,
}

impl GainState for ModularFacadeState<'_> {
    fn value(&self) -> f64 {
        self.value
    }

    fn gain(&self, e: ElemId) -> f64 {
        if self.solution.contains(&e) {
            0.0
        } else {
            self.weights[self.oracle.local(e) as usize]
        }
    }

    fn commit(&mut self, e: ElemId) {
        if !self.solution.contains(&e) {
            self.value += self.weights[self.oracle.local(e) as usize];
            self.solution.push(e);
        }
    }

    fn solution(&self) -> &[ElemId] {
        &self.solution
    }

    fn call_cost(&self, _e: ElemId) -> u64 {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::{
        FacilityLocation, KCover, KDominatingSet, KMedoid, Modular, WeightedCover,
    };
    use crate::util::rng::Rng;

    /// extract → JSON round-trip → rebuild, then compare gains, commits
    /// and values against the original oracle over the shipped elements.
    /// `view` restricts evaluation on *both* sides (the k-medoid local
    /// scheme); gains must agree to the last bit.
    fn roundtrip_parity(oracle: &dyn Oracle, elems: &[ElemId], local_view: bool, seed: u64) {
        let p = oracle.partitionable().expect("oracle must be partitionable");
        let payload = p.extract_partition(elems);
        assert_eq!(payload.len(), elems.len());
        assert_eq!(payload.n_global, oracle.n());

        // Serde stability: the JSON document rebuilds the same payload.
        let reparsed = PartitionPayload::from_value(&payload.to_value()).unwrap();
        assert_eq!(reparsed, payload);

        // Binary stability: the v5 section encoding rebuilds it too, and
        // binary_len predicts the encoded size exactly.
        let mut bin = Vec::new();
        payload.encode_binary(&mut bin);
        assert_eq!(bin.len(), payload.binary_len(), "binary_len must match the encoding");
        assert_eq!(PartitionPayload::decode_binary(&bin).unwrap(), payload);

        let facade = PartitionOracle::from_payload(&reparsed).unwrap();
        assert_eq!(facade.n(), oracle.n(), "facade speaks the global id space");
        assert_eq!(facade.len_local(), elems.len());
        assert_eq!(facade.name(), oracle.name());

        let view = local_view.then_some(elems);
        let mut a = oracle.new_state(view);
        let mut b = facade.new_state(view);
        let mut order: Vec<ElemId> = elems.to_vec();
        Rng::new(seed).shuffle(&mut order);
        for (round, &e) in order.iter().enumerate() {
            for &q in &order {
                assert_eq!(
                    a.gain(q).to_bits(),
                    b.gain(q).to_bits(),
                    "{}: gain({q}) diverged at round {round}",
                    oracle.name()
                );
                assert_eq!(a.call_cost(q), b.call_cost(q), "call_cost({q})");
            }
            let mut ga = Vec::new();
            let mut gb = Vec::new();
            a.gain_batch(&order, &mut ga);
            b.gain_batch(&order, &mut gb);
            let bits = |v: &[f64]| v.iter().map(|g| g.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&ga), bits(&gb), "{}: gain_batch", oracle.name());
            if round < 4 {
                a.commit(e);
                b.commit(e);
                assert_eq!(
                    a.value().to_bits(),
                    b.value().to_bits(),
                    "{}: value after commit {e}",
                    oracle.name()
                );
                assert_eq!(a.solution(), b.solution());
            }
        }
        for &e in elems {
            assert_eq!(oracle.elem_bytes(e), facade.elem_bytes(e), "elem_bytes({e})");
        }
    }

    fn cover_oracle(n: usize) -> KCover {
        KCover::new(Arc::new(crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: n,
                num_items: n / 2,
                mean_size: 6.0,
                zipf_s: 0.9,
            },
            7,
        )))
    }

    fn shard(n: usize, take: usize, seed: u64) -> Vec<ElemId> {
        let mut ids: Vec<ElemId> = (0..n as ElemId).collect();
        Rng::new(seed).shuffle(&mut ids);
        ids.truncate(take);
        ids
    }

    #[test]
    fn kcover_partition_roundtrip_parity() {
        let o = cover_oracle(200);
        roundtrip_parity(&o, &shard(200, 60, 1), false, 11);
    }

    #[test]
    fn weighted_cover_partition_roundtrip_parity() {
        let data = Arc::new(crate::data::gen::transactions(
            crate::data::gen::TransactionParams {
                num_sets: 150,
                num_items: 80,
                mean_size: 5.0,
                zipf_s: 1.0,
            },
            3,
        ));
        let o = WeightedCover::zipf(data, 0.8);
        roundtrip_parity(&o, &shard(150, 50, 2), false, 12);
    }

    #[test]
    fn kdominate_partition_roundtrip_parity_both_variants() {
        let g = Arc::new(crate::data::gen::barabasi_albert(300, 3, 5));
        roundtrip_parity(&KDominatingSet::new(g.clone()), &shard(300, 80, 3), false, 13);
        roundtrip_parity(&KDominatingSet::closed(g), &shard(300, 80, 4), false, 14);
    }

    #[test]
    fn kmedoid_partition_roundtrip_parity_under_local_view() {
        let (vs, _) = crate::data::gen::gaussian_mixture(
            crate::data::gen::GaussianParams { n: 120, dim: 9, classes: 4, noise: 0.4 },
            6,
        );
        let o = KMedoid::new(Arc::new(vs));
        assert!(o.partitionable().unwrap().needs_local_view());
        roundtrip_parity(&o, &shard(120, 40, 5), true, 15);
    }

    #[test]
    fn facility_partition_roundtrip_parity() {
        let o = FacilityLocation::random(12, 60, 9);
        roundtrip_parity(&o, &shard(60, 20, 6), false, 16);
    }

    #[test]
    fn modular_partition_roundtrip_parity() {
        let o = Modular::random(80, 4);
        roundtrip_parity(&o, &shard(80, 30, 7), false, 17);
    }

    #[test]
    fn payload_wire_bytes_scale_with_the_shard_not_the_dataset() {
        // The whole point of partition shipping: a worker's Init payload
        // is ≈ 1/m of the full dataset's footprint, not O(n).
        let n = 600;
        let m = 4;
        let o = cover_oracle(n);
        let p = o.partitionable().unwrap();
        let full = p.extract_partition(&(0..n as ElemId).collect::<Vec<_>>()).wire_bytes();
        let mut ids: Vec<ElemId> = (0..n as ElemId).collect();
        Rng::new(9).shuffle(&mut ids);
        let mut total = 0usize;
        for chunk in ids.chunks(n / m) {
            let bytes = p.extract_partition(chunk).wire_bytes();
            assert!(
                bytes < full * 2 / m,
                "one shard of {m} weighs {bytes} of {full} full bytes"
            );
            total += bytes;
        }
        // Shards tile the dataset: together they carry all the data plus
        // per-shard envelope overhead.
        assert!(total >= full * 8 / 10, "shards total {total} vs full {full}");
    }

    #[test]
    fn ingest_extends_the_shard_and_extract_reslices_it() {
        let o = cover_oracle(100);
        let p = o.partitionable().unwrap();
        let a: Vec<ElemId> = (0..40).collect();
        let b: Vec<ElemId> = (30..70).collect(); // overlaps a
        let mut facade = PartitionOracle::from_payload(&p.extract_partition(&a)).unwrap();
        facade.ingest(&p.extract_partition(&b)).unwrap();
        assert_eq!(facade.len_local(), 70, "overlap ingested once");
        // Gains over the union match the full oracle bit for bit.
        let sa = o.new_state(None);
        let sb = facade.new_state(None);
        for e in 0..70u32 {
            assert_eq!(sa.gain(e).to_bits(), sb.gain(e).to_bits(), "gain({e})");
        }
        // Re-extracting a mixed solution round-trips through a fresh facade.
        let sol = vec![5, 65, 33];
        let shipped = facade.extract(&sol).unwrap();
        let rebuilt = PartitionOracle::from_payload(&shipped).unwrap();
        let sr = rebuilt.new_state(None);
        for &e in &sol {
            assert_eq!(sa.gain(e).to_bits(), sr.gain(e).to_bits());
        }
        assert!(facade.extract(&[99]).is_err(), "unknown element refuses to extract");
    }

    #[test]
    fn apply_delta_inserts_deletes_and_compacts_like_a_cold_rebuild() {
        let base = PartitionPayload {
            n_global: 6,
            elems: vec![0, 2, 4],
            data: PartitionData::Modular { weights: vec![1.0, 2.0, 3.0] },
        };
        let mut live = PartitionOracle::from_payload(&base).unwrap();
        // Ground set grows to 8; delete 2 (held) and 5 (owned elsewhere,
        // skipped here); insert 6 and 7.
        let delta = PartitionDelta {
            n_global: 8,
            insert: PartitionPayload {
                n_global: 8,
                elems: vec![6, 7],
                data: PartitionData::Modular { weights: vec![4.0, 5.0] },
            },
            delete: vec![2, 5],
        };
        live.apply_delta(&delta).unwrap();
        assert_eq!(live.n(), 8, "facade adopts the post-delta ground set");
        assert_eq!(live.len_local(), 4);
        assert!(!live.holds(2), "deleted element left the shard");
        // A cold rebuild of the post-delta shard (survivors in original
        // order, inserts appended) must be structurally identical.
        let cold = PartitionOracle::from_payload(&PartitionPayload {
            n_global: 8,
            elems: vec![0, 4, 6, 7],
            data: PartitionData::Modular { weights: vec![1.0, 3.0, 4.0, 5.0] },
        })
        .unwrap();
        let post = [0u32, 4, 6, 7];
        let (sa, sb) = (live.new_state(None), cold.new_state(None));
        for &e in &post {
            assert_eq!(sa.gain(e).to_bits(), sb.gain(e).to_bits(), "gain({e})");
            assert_eq!(live.elem_bytes(e), cold.elem_bytes(e), "elem_bytes({e})");
        }
        assert_eq!(live.extract(&post).unwrap(), cold.extract(&post).unwrap());
    }

    #[test]
    fn apply_delta_on_a_cover_shard_matches_re_extraction() {
        // The incremental-vs-cold identity on real CSR data: a live shard
        // after (delete, insert) extracts exactly what the original
        // oracle extracts for the post-delta element list.
        let o = cover_oracle(100);
        let p = o.partitionable().unwrap();
        let base: Vec<ElemId> = (0..40).collect();
        let mut live = PartitionOracle::from_payload(&p.extract_partition(&base)).unwrap();
        let delta = PartitionDelta {
            n_global: 100,
            insert: p.extract_partition(&[50, 60]),
            delete: vec![5, 7, 93],
        };
        live.apply_delta(&delta).unwrap();
        let post: Vec<ElemId> = base
            .iter()
            .copied()
            .filter(|e| ![5, 7].contains(e))
            .chain([50, 60])
            .collect();
        assert_eq!(live.len_local(), post.len());
        assert_eq!(live.extract(&post).unwrap(), p.extract_partition(&post));
        // Gains over the live shard still match the full oracle.
        let (sa, sb) = (o.new_state(None), live.new_state(None));
        for &e in &post {
            assert_eq!(sa.gain(e).to_bits(), sb.gain(e).to_bits(), "gain({e})");
        }
    }

    #[test]
    fn delta_json_and_binary_codecs_roundtrip() {
        let o = cover_oracle(100);
        let p = o.partitionable().unwrap();
        let delta = PartitionDelta {
            n_global: 100,
            insert: p.extract_partition(&[10, 20, 30]),
            delete: vec![3, 96],
        };
        delta.validate().unwrap();
        assert_eq!(PartitionDelta::from_value(&delta.to_value()).unwrap(), delta);
        let mut bin = Vec::new();
        delta.encode_binary(&mut bin);
        assert_eq!(bin.len(), delta.binary_len(), "binary_len must match the encoding");
        assert_eq!(PartitionDelta::decode_binary(&bin).unwrap(), delta);
        // Deletes-only deltas ship an empty insert payload of the family.
        let bare = PartitionDelta {
            n_global: 100,
            insert: p.extract_partition(&[]),
            delete: vec![1],
        };
        assert_eq!(PartitionDelta::from_value(&bare.to_value()).unwrap(), bare);
        let mut bin = Vec::new();
        bare.encode_binary(&mut bin);
        assert_eq!(bin.len(), bare.binary_len());
        assert_eq!(PartitionDelta::decode_binary(&bin).unwrap(), bare);
    }

    #[test]
    fn malformed_deltas_are_rejected() {
        let ins = |n: usize, elems: Vec<ElemId>, w: Vec<f64>| PartitionPayload {
            n_global: n,
            elems,
            data: PartitionData::Modular { weights: w },
        };
        // Insert payload disagreeing with the declared post-delta n.
        let d = PartitionDelta { n_global: 8, insert: ins(6, vec![], vec![]), delete: vec![] };
        assert!(d.validate().is_err());
        // Delete outside the ground set / duplicated / also inserted.
        let d = PartitionDelta { n_global: 8, insert: ins(8, vec![], vec![]), delete: vec![8] };
        assert!(d.validate().is_err());
        let d =
            PartitionDelta { n_global: 8, insert: ins(8, vec![], vec![]), delete: vec![1, 1] };
        assert!(d.validate().is_err());
        let d = PartitionDelta {
            n_global: 8,
            insert: ins(8, vec![6], vec![1.0]),
            delete: vec![6],
        };
        assert!(d.validate().is_err());
        // Application-time refusals: shrinking, re-inserting a held id,
        // family mismatch.
        let mut live = PartitionOracle::from_payload(&ins(6, vec![0, 2], vec![1.0, 2.0]))
            .unwrap();
        let shrink =
            PartitionDelta { n_global: 4, insert: ins(4, vec![], vec![]), delete: vec![] };
        assert!(live.apply_delta(&shrink).is_err(), "ground set never contracts");
        let clash = PartitionDelta {
            n_global: 6,
            insert: ins(6, vec![2], vec![9.0]),
            delete: vec![],
        };
        assert!(live.apply_delta(&clash).is_err(), "re-inserting a live id is refused");
        let wrong_family = PartitionDelta {
            n_global: 6,
            insert: PartitionPayload {
                n_global: 6,
                elems: vec![],
                data: PartitionData::Vectors { dim: 2, flat: vec![] },
            },
            delete: vec![],
        };
        assert!(live.apply_delta(&wrong_family).is_err(), "family mismatch is refused");
    }

    #[test]
    fn streaming_decode_matches_one_shot_byte_at_a_time() {
        // The overlap path's contract: feeding the frame 1 byte at a time
        // builds exactly the payload a one-shot decode builds, and the
        // ready-section count only ever moves forward.
        let o = cover_oracle(120);
        let payload = o.partitionable().unwrap().extract_partition(&shard(120, 40, 21));
        let mut bin = Vec::new();
        payload.encode_binary(&mut bin);
        let mut dec = PartitionDecoder::new(bin.len());
        let mut ready = 0;
        for (i, b) in bin.iter().enumerate() {
            assert!(!dec.is_complete(), "complete before byte {i} of {}", bin.len());
            dec.feed(std::slice::from_ref(b)).unwrap();
            let now = dec.ready_sections();
            assert!(now >= ready, "ready sections regressed at byte {i}");
            ready = now;
        }
        assert!(dec.is_complete());
        assert_eq!(dec.total_sections(), Some(3));
        assert_eq!(ready, 3, "every section completed");
        let streamed = dec.finish().unwrap();
        assert_eq!(streamed, PartitionPayload::decode_binary(&bin).unwrap());
        assert_eq!(streamed, payload);
        // The incrementally-built oracle serves the same gains.
        let facade = PartitionOracle::from_payload(&streamed).unwrap();
        let (sa, sb) = (o.new_state(None), facade.new_state(None));
        for &e in &payload.elems {
            assert_eq!(sa.gain(e).to_bits(), sb.gain(e).to_bits(), "gain({e})");
        }
    }

    #[test]
    fn streaming_decode_matches_one_shot_in_random_chunks() {
        let o = FacilityLocation::random(9, 70, 5);
        let payload = o.partitionable().unwrap().extract_partition(&shard(70, 25, 22));
        let mut bin = Vec::new();
        payload.encode_binary(&mut bin);
        let mut rng = Rng::new(404);
        for _ in 0..20 {
            let mut dec = PartitionDecoder::new(bin.len());
            let mut at = 0;
            let mut ready = 0;
            while at < bin.len() {
                let take = 1 + rng.below((bin.len() - at).min(37) as u64) as usize;
                dec.feed(&bin[at..at + take]).unwrap();
                assert!(dec.ready_sections() >= ready, "ready sections regressed");
                ready = dec.ready_sections();
                at += take;
            }
            assert!(dec.is_complete());
            assert_eq!(dec.finish().unwrap(), payload);
        }
    }

    #[test]
    fn empty_shard_binary_roundtrip() {
        // Zero-length sections at the tail must complete without any
        // further feed bytes arriving to drive them.
        let payload = PartitionPayload {
            n_global: 10,
            elems: vec![],
            data: PartitionData::Modular { weights: vec![] },
        };
        let mut bin = Vec::new();
        payload.encode_binary(&mut bin);
        assert_eq!(bin.len(), HEADER_FIXED + 2 * SECTION_DESC, "header only");
        let mut dec = PartitionDecoder::new(bin.len());
        dec.feed(&bin).unwrap();
        assert!(dec.is_complete());
        assert_eq!(dec.ready_sections(), 2);
        assert_eq!(dec.finish().unwrap(), payload);
    }

    #[test]
    fn decoder_overfeed_and_truncation_are_errors_not_panics() {
        let payload = PartitionPayload {
            n_global: 8,
            elems: vec![1, 4],
            data: PartitionData::Modular { weights: vec![0.5, 2.5] },
        };
        let mut bin = Vec::new();
        payload.encode_binary(&mut bin);
        // One byte past the declared length is refused at feed time.
        let mut dec = PartitionDecoder::new(bin.len() - 1);
        let mut extra = bin.clone();
        extra.push(0);
        assert!(dec.feed(&extra).is_err(), "overfeed must be refused");
        // A short frame finishes with a truncation error, never a panic.
        for cut in 0..bin.len() {
            let mut dec = PartitionDecoder::new(cut);
            let err = dec
                .feed(&bin[..cut])
                .err()
                .or_else(|| dec.finish().err())
                .expect("truncated payload must be an error");
            assert!(err.contains("binary payload"), "untyped error: {err}");
        }
    }

    #[test]
    fn hostile_section_lengths_are_rejected_before_allocation() {
        // A header declaring gigabytes in its section table must be
        // refused by the sum check — the frame is tiny, so nothing sized
        // by the declared lengths may be allocated.
        let payload = PartitionPayload {
            n_global: 8,
            elems: vec![1, 4],
            data: PartitionData::Modular { weights: vec![0.5, 2.5] },
        };
        let mut bin = Vec::new();
        payload.encode_binary(&mut bin);
        let mut hostile = bin.clone();
        // Section 0's declared length → 2^40 bytes.
        hostile[HEADER_FIXED..HEADER_FIXED + 8]
            .copy_from_slice(&(1u64 << 40).to_le_bytes());
        let mut dec = PartitionDecoder::new(hostile.len());
        let err = dec
            .feed(&hostile)
            .err()
            .or_else(|| dec.finish().err())
            .expect("oversized declared length must be an error");
        assert!(err.contains("declares"), "sum check should trip: {err}");
    }

    #[test]
    fn f32_rows_survive_the_binary_codec_bit_exactly() {
        let payload = PartitionPayload {
            n_global: 4,
            elems: vec![2, 0],
            data: PartitionData::Vectors {
                dim: 3,
                flat: vec![0.1f32, -2.5e-30, 3.4e38, 1.0 / 3.0, f32::MIN_POSITIVE, -0.0],
            },
        };
        let mut bin = Vec::new();
        payload.encode_binary(&mut bin);
        let back = PartitionPayload::decode_binary(&bin).unwrap();
        match (&payload.data, &back.data) {
            (PartitionData::Vectors { flat: a, .. }, PartitionData::Vectors { flat: b, .. }) => {
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(a), bits(b));
            }
            _ => panic!("family changed in flight"),
        }
    }

    #[test]
    fn f32_rows_survive_the_json_codec_bit_exactly() {
        let payload = PartitionPayload {
            n_global: 4,
            elems: vec![2, 0],
            data: PartitionData::Vectors {
                dim: 3,
                flat: vec![0.1f32, -2.5e-30, 3.4e38, 1.0 / 3.0, f32::MIN_POSITIVE, 0.0],
            },
        };
        let back = PartitionPayload::from_value(&payload.to_value()).unwrap();
        match (&payload.data, &back.data) {
            (PartitionData::Vectors { flat: a, .. }, PartitionData::Vectors { flat: b, .. }) => {
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(a), bits(b));
            }
            _ => panic!("family changed in flight"),
        }
    }

    #[test]
    fn malformed_payloads_are_rejected() {
        let bad = PartitionPayload {
            n_global: 10,
            elems: vec![3, 11],
            data: PartitionData::Modular { weights: vec![1.0, 2.0] },
        };
        assert!(bad.validate().is_err(), "element beyond n_global");
        let short = PartitionPayload {
            n_global: 10,
            elems: vec![1, 2],
            data: PartitionData::Modular { weights: vec![1.0] },
        };
        assert!(PartitionOracle::from_payload(&short).is_err());
        let dup = PartitionPayload {
            n_global: 10,
            elems: vec![1, 1],
            data: PartitionData::Modular { weights: vec![1.0, 1.0] },
        };
        assert!(PartitionOracle::from_payload(&dup).is_err(), "duplicate element");
        // Duplicates are caught by validate(), so ingest refuses them too
        // (a buggy peer must fail the protocol, not bloat the shard).
        let mut facade = PartitionOracle::from_payload(&PartitionPayload {
            n_global: 10,
            elems: vec![0],
            data: PartitionData::Modular { weights: vec![1.0] },
        })
        .unwrap();
        assert!(facade.ingest(&dup).is_err(), "ingest rejects duplicate elements");
        let skewed = PartitionPayload {
            n_global: 10,
            elems: vec![2],
            data: PartitionData::Cover {
                universe: 9,
                offsets: vec![1, 2], // CSR must start at 0
                items: vec![7, 8],
                weights: None,
                self_cover: false,
                dominating: false,
            },
        };
        assert!(skewed.validate().is_err(), "nonzero first offset is malformed");
    }
}
