//! The maximum k-vertex-dominating-set objective (§4.2).
//!
//! Ground set = vertices of a graph; a vertex dominates its adjacent
//! vertices δ(u) (the paper's definition — open neighbourhood), and
//! `f(S) = |∪_{u∈S} δ(u)|`.  A `closed` option additionally counts the
//! vertex itself (the more common textbook definition); the benches use the
//! paper's open variant.

use super::problem::{PartitionData, PartitionPayload, Partitionable};
use super::{GainState, Oracle};
use crate::data::graph::CsrGraph;
use crate::util::bitset::BitSet;
use crate::ElemId;
use std::sync::Arc;

/// k-dominating-set oracle over an undirected graph.
#[derive(Clone)]
pub struct KDominatingSet {
    graph: Arc<CsrGraph>,
    closed: bool,
}

impl KDominatingSet {
    /// Paper variant: `u` dominates exactly its neighbours.
    pub fn new(graph: Arc<CsrGraph>) -> Self {
        Self { graph, closed: false }
    }

    /// Closed-neighbourhood variant: `u` also dominates itself.
    pub fn closed(graph: Arc<CsrGraph>) -> Self {
        Self { graph, closed: true }
    }

    /// The underlying graph.
    pub fn graph(&self) -> &CsrGraph {
        &self.graph
    }
}

impl Oracle for KDominatingSet {
    fn n(&self) -> usize {
        self.graph.num_vertices()
    }

    fn name(&self) -> &'static str {
        "k-dominating-set"
    }

    fn new_state<'a>(&'a self, _view: Option<&[ElemId]>) -> Box<dyn GainState + 'a> {
        Box::new(KDomState {
            graph: &self.graph,
            closed: self.closed,
            covered: BitSet::new(self.graph.num_vertices()),
            covered_count: 0,
            solution: Vec::new(),
        })
    }

    fn elem_bytes(&self, e: ElemId) -> usize {
        self.graph.elem_bytes(e)
    }

    fn partitionable(&self) -> Option<&dyn Partitionable> {
        Some(self)
    }
}

impl Partitionable for KDominatingSet {
    fn extract_partition(&self, elems: &[ElemId]) -> PartitionPayload {
        // Per-vertex adjacency lists in global vertex ids: the covered
        // universe is the whole graph even though only the shard's
        // vertices are candidates.  The closed variant's self-domination
        // rides on the payload's `self_cover` flag (the self "item" is the
        // element's own global id, which the shard carries in `elems`).
        let (offsets, items) = self.graph.neighborhoods(elems);
        PartitionPayload {
            n_global: self.graph.num_vertices(),
            elems: elems.to_vec(),
            data: PartitionData::Cover {
                universe: self.graph.num_vertices(),
                offsets,
                items,
                weights: None,
                self_cover: self.closed,
                dominating: true,
            },
        }
    }
}

struct KDomState<'a> {
    graph: &'a CsrGraph,
    closed: bool,
    covered: BitSet,
    covered_count: usize,
    solution: Vec<ElemId>,
}

impl GainState for KDomState<'_> {
    fn value(&self) -> f64 {
        self.covered_count as f64
    }

    #[inline]
    fn gain(&self, e: ElemId) -> f64 {
        let mut g = self.covered.union_gain_sparse(self.graph.neighbors(e));
        if self.closed {
            g += !self.covered.contains(e as usize) as usize;
        }
        g as f64
    }

    fn commit(&mut self, e: ElemId) {
        self.covered_count += self.covered.insert_sparse(self.graph.neighbors(e));
        if self.closed {
            self.covered_count += self.covered.insert(e as usize) as usize;
        }
        self.solution.push(e);
    }

    fn solution(&self) -> &[ElemId] {
        &self.solution
    }

    fn call_cost(&self, e: ElemId) -> u64 {
        self.graph.degree(e) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::testutil;

    /// Star: 0 is the hub of 1..=4; 5-6 an edge apart.
    fn star() -> Arc<CsrGraph> {
        Arc::new(CsrGraph::from_edges(7, &[(0, 1), (0, 2), (0, 3), (0, 4), (5, 6)]))
    }

    #[test]
    fn open_neighbourhood_values() {
        let o = KDominatingSet::new(star());
        assert_eq!(o.eval(&[0]), 4.0);
        assert_eq!(o.eval(&[1]), 1.0);
        assert_eq!(o.eval(&[0, 1]), 5.0, "1 dominates 0");
        assert_eq!(o.eval(&[0, 5]), 5.0);
        assert_eq!(o.eval(&[0, 1, 5, 6]), 7.0);
    }

    #[test]
    fn closed_neighbourhood_values() {
        let o = KDominatingSet::closed(star());
        assert_eq!(o.eval(&[0]), 5.0);
        assert_eq!(o.eval(&[5]), 2.0);
    }

    #[test]
    fn submodular_and_incremental_both_variants() {
        let mut rng = crate::util::rng::Rng::new(4);
        let g = Arc::new(crate::data::gen::road(
            crate::data::gen::RoadParams { n: 64, ..Default::default() },
            3,
        ));
        for o in [KDominatingSet::new(g.clone()), KDominatingSet::closed(g.clone())] {
            testutil::check_submodular(&o, &mut rng, 40);
            testutil::check_incremental(&o, &mut rng);
        }
    }

    #[test]
    fn call_cost_is_degree() {
        let o = KDominatingSet::new(star());
        let st = o.new_state(None);
        assert_eq!(st.call_cost(0), 4);
        assert_eq!(st.call_cost(5), 1);
    }
}
