//! The maximum k-cover objective (§4.2).
//!
//! Ground set = transactions of an [`ItemsetCollection`]; `f(S)` = number of
//! distinct items covered by the union of the chosen transactions.  The
//! marginal gain of transaction `t` is the count of its items not yet
//! covered — `O(δ)` per call with a packed bitmap (Table 1).

use super::problem::{PartitionData, PartitionPayload, Partitionable};
use super::{GainState, Oracle};
use crate::data::itemsets::ItemsetCollection;
use crate::util::bitset::BitSet;
use crate::ElemId;
use std::sync::Arc;

/// k-cover oracle over a transaction collection.
#[derive(Clone)]
pub struct KCover {
    data: Arc<ItemsetCollection>,
}

impl KCover {
    /// Wrap a collection.
    pub fn new(data: Arc<ItemsetCollection>) -> Self {
        Self { data }
    }

    /// The underlying collection.
    pub fn data(&self) -> &ItemsetCollection {
        &self.data
    }
}

impl Oracle for KCover {
    fn n(&self) -> usize {
        self.data.num_sets()
    }

    fn name(&self) -> &'static str {
        "k-cover"
    }

    fn new_state<'a>(&'a self, _view: Option<&[ElemId]>) -> Box<dyn GainState + 'a> {
        // Coverage is defined over the item universe regardless of which
        // transactions are locally present, so the view is irrelevant.
        Box::new(KCoverState {
            data: &self.data,
            covered: BitSet::new(self.data.num_items()),
            covered_count: 0,
            solution: Vec::new(),
        })
    }

    fn elem_bytes(&self, e: ElemId) -> usize {
        self.data.elem_bytes(e)
    }

    fn partitionable(&self) -> Option<&dyn Partitionable> {
        Some(self)
    }
}

impl Partitionable for KCover {
    fn extract_partition(&self, elems: &[ElemId]) -> PartitionPayload {
        let (offsets, items) = self.data.slice_sets(elems);
        PartitionPayload {
            n_global: self.data.num_sets(),
            elems: elems.to_vec(),
            data: PartitionData::Cover {
                universe: self.data.num_items(),
                offsets,
                items,
                weights: None,
                self_cover: false,
                dominating: false,
            },
        }
    }
}

struct KCoverState<'a> {
    data: &'a ItemsetCollection,
    covered: BitSet,
    covered_count: usize,
    solution: Vec<ElemId>,
}

impl GainState for KCoverState<'_> {
    fn value(&self) -> f64 {
        self.covered_count as f64
    }

    #[inline]
    fn gain(&self, e: ElemId) -> f64 {
        self.covered.union_gain_sparse(self.data.set(e)) as f64
    }

    fn commit(&mut self, e: ElemId) {
        self.covered_count += self.covered.insert_sparse(self.data.set(e));
        self.solution.push(e);
    }

    fn solution(&self) -> &[ElemId] {
        &self.solution
    }

    fn call_cost(&self, e: ElemId) -> u64 {
        self.data.set_size(e) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::testutil;

    fn oracle() -> KCover {
        KCover::new(Arc::new(ItemsetCollection::from_sets(&[
            vec![0, 1, 2],
            vec![2, 3],
            vec![3, 4, 5, 6],
            vec![0, 6],
            vec![],
        ])))
    }

    #[test]
    fn values_match_hand_computation() {
        let o = oracle();
        assert_eq!(o.eval(&[]), 0.0);
        assert_eq!(o.eval(&[0]), 3.0);
        assert_eq!(o.eval(&[0, 1]), 4.0);
        assert_eq!(o.eval(&[0, 1, 2]), 7.0);
        assert_eq!(o.eval(&[0, 1, 2, 3, 4]), 7.0);
        assert_eq!(o.eval(&[4]), 0.0, "empty transaction covers nothing");
    }

    #[test]
    fn gains_and_commits() {
        let o = oracle();
        let mut st = o.new_state(None);
        assert_eq!(st.gain(2), 4.0);
        st.commit(2);
        assert_eq!(st.gain(1), 1.0, "item 3 already covered");
        assert_eq!(st.call_cost(2), 4);
        assert_eq!(st.call_cost(4), 0);
    }

    #[test]
    fn is_submodular_and_incremental() {
        let o = oracle();
        let mut rng = crate::util::rng::Rng::new(2);
        testutil::check_submodular(&o, &mut rng, 60);
        testutil::check_incremental(&o, &mut rng);
    }

    #[test]
    fn batch_matches_single() {
        let o = oracle();
        let st = o.new_state(None);
        let mut out = Vec::new();
        st.gain_batch(&[0, 1, 2, 3, 4], &mut out);
        let single: Vec<f64> = (0..5).map(|e| st.gain(e)).collect();
        assert_eq!(out, single);
    }
}
