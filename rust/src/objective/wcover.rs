//! Weighted k-cover: `f(S) = Σ_{i ∈ ∪ S} w_i` with non-negative item
//! weights — the budgeted/document-summarization generalization of k-cover
//! (Lin & Bilmes, the paper's [18,19] motivation).  Plain k-cover is the
//! `w ≡ 1` special case, which the tests exploit as an oracle-vs-oracle
//! consistency check.

use super::problem::{slice_weights, PartitionData, PartitionPayload, Partitionable};
use super::{GainState, Oracle};
use crate::data::itemsets::ItemsetCollection;
use crate::util::bitset::BitSet;
use crate::ElemId;
use std::sync::Arc;

/// Weighted coverage oracle over a transaction collection.
#[derive(Clone)]
pub struct WeightedCover {
    data: Arc<ItemsetCollection>,
    weights: Arc<Vec<f64>>,
}

impl WeightedCover {
    /// Build with per-item weights (must be ≥ 0 and cover the universe).
    pub fn new(data: Arc<ItemsetCollection>, weights: Vec<f64>) -> crate::Result<Self> {
        anyhow::ensure!(
            weights.len() >= data.num_items(),
            "need {} item weights, got {}",
            data.num_items(),
            weights.len()
        );
        anyhow::ensure!(
            weights.iter().all(|&w| w >= 0.0),
            "item weights must be non-negative (monotonicity)"
        );
        Ok(Self { data, weights: Arc::new(weights) })
    }

    /// Uniform weights — equivalent to plain [`super::KCover`].
    pub fn uniform(data: Arc<ItemsetCollection>) -> Self {
        let n = data.num_items();
        Self { data, weights: Arc::new(vec![1.0; n]) }
    }

    /// Zipf-decaying weights by item id (popular-item emphasis), seeded.
    pub fn zipf(data: Arc<ItemsetCollection>, s: f64) -> Self {
        let n = data.num_items();
        let weights = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(s)).collect();
        Self { data, weights: Arc::new(weights) }
    }
}

impl Oracle for WeightedCover {
    fn n(&self) -> usize {
        self.data.num_sets()
    }

    fn name(&self) -> &'static str {
        "weighted-cover"
    }

    fn new_state<'a>(&'a self, _view: Option<&[ElemId]>) -> Box<dyn GainState + 'a> {
        Box::new(WCoverState {
            oracle: self,
            covered: BitSet::new(self.data.num_items()),
            value: 0.0,
            solution: Vec::new(),
        })
    }

    fn elem_bytes(&self, e: ElemId) -> usize {
        self.data.elem_bytes(e)
    }

    fn partitionable(&self) -> Option<&dyn Partitionable> {
        Some(self)
    }
}

impl Partitionable for WeightedCover {
    fn extract_partition(&self, elems: &[ElemId]) -> PartitionPayload {
        let (offsets, items) = self.data.slice_sets(elems);
        // Ship weights only for the items the shard's sets actually touch
        // — the full weight vector is O(universe), defeating the O(n/m)
        // payload; a shard's gain queries never look past its own items.
        let weights = slice_weights(&items, |i| self.weights[i as usize]);
        PartitionPayload {
            n_global: self.data.num_sets(),
            elems: elems.to_vec(),
            data: PartitionData::Cover {
                universe: self.data.num_items(),
                offsets,
                items,
                weights: Some(weights),
                self_cover: false,
                dominating: false,
            },
        }
    }
}

struct WCoverState<'a> {
    oracle: &'a WeightedCover,
    covered: BitSet,
    value: f64,
    solution: Vec<ElemId>,
}

impl GainState for WCoverState<'_> {
    fn value(&self) -> f64 {
        self.value
    }

    #[inline]
    fn gain(&self, e: ElemId) -> f64 {
        let w = &self.oracle.weights;
        self.oracle
            .data
            .set(e)
            .iter()
            .filter(|&&i| !self.covered.contains(i as usize))
            .map(|&i| w[i as usize])
            .sum()
    }

    fn commit(&mut self, e: ElemId) {
        for &i in self.oracle.data.set(e) {
            if self.covered.insert(i as usize) {
                self.value += self.oracle.weights[i as usize];
            }
        }
        self.solution.push(e);
    }

    fn solution(&self) -> &[ElemId] {
        &self.solution
    }

    fn call_cost(&self, e: ElemId) -> u64 {
        self.oracle.data.set_size(e) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::{testutil, KCover};

    fn data() -> Arc<ItemsetCollection> {
        Arc::new(ItemsetCollection::from_sets(&[
            vec![0, 1, 2],
            vec![2, 3],
            vec![3, 4, 5],
            vec![0, 5],
        ]))
    }

    #[test]
    fn uniform_matches_kcover_exactly() {
        let d = data();
        let w = WeightedCover::uniform(d.clone());
        let k = KCover::new(d);
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..40 {
            let mut sol: Vec<u32> = (0..4).collect();
            rng.shuffle(&mut sol);
            let take = rng.below(5) as usize;
            assert_eq!(w.eval(&sol[..take]), k.eval(&sol[..take]));
        }
    }

    #[test]
    fn weights_change_the_argmax() {
        let d = data();
        // Item 4 is worth everything: transaction 2 must win first.
        let mut weights = vec![0.01; 6];
        weights[4] = 100.0;
        let o = WeightedCover::new(d, weights).unwrap();
        let c = crate::constraint::Cardinality::new(1);
        let out = crate::greedy::greedy_lazy(&o, &c, &[0, 1, 2, 3], None);
        assert_eq!(out.solution, vec![2]);
        assert!(out.value > 100.0);
    }

    #[test]
    fn submodular_and_incremental() {
        let o = WeightedCover::zipf(data(), 1.0);
        let mut rng = crate::util::rng::Rng::new(8);
        testutil::check_submodular(&o, &mut rng, 50);
        testutil::check_incremental(&o, &mut rng);
    }

    #[test]
    fn rejects_bad_weights() {
        assert!(WeightedCover::new(data(), vec![1.0; 2]).is_err());
        assert!(WeightedCover::new(data(), vec![1.0, 1.0, 1.0, 1.0, 1.0, -0.1]).is_err());
    }

    #[test]
    fn works_under_greedyml() {
        let d = Arc::new(crate::data::gen::transactions(
            crate::data::gen::TransactionParams::retail_like(800),
            4,
        ));
        let o = WeightedCover::zipf(d, 0.8);
        let c = crate::constraint::Cardinality::new(10);
        let cfg = crate::algo::DistConfig::greedyml(crate::tree::AccumulationTree::new(4, 2), 3);
        let out = crate::algo::run_greedyml(&o, &c, &cfg).unwrap();
        assert!(out.value > 0.0);
        assert!((out.value - o.eval(&out.solution)).abs() < 1e-9);
    }
}
