//! Facility-location objective: `f(S) = Σ_i max_{j∈S} w[i][j]` (w ≥ 0).
//!
//! A classic monotone submodular function distinct in structure from both
//! coverage (integer, sparse) and k-medoid (metric): it exercises dense
//! max-accumulation with real-valued weights.  Used by the property suite
//! and by the ablation benches as a third objective family; small/dense by
//! construction (`n × n` weight matrix), so it also gives the brute-force
//! OPT tests a fast oracle.

use super::problem::{PartitionData, PartitionPayload, Partitionable};
use super::{GainState, Oracle};
use crate::ElemId;

/// Facility-location oracle over a dense non-negative benefit matrix
/// (row = client, column = facility candidate).
#[derive(Clone, Debug)]
pub struct FacilityLocation {
    /// Row-major `clients × n` benefit matrix.
    w: Vec<f64>,
    clients: usize,
    n: usize,
}

impl FacilityLocation {
    /// Build from a row-major matrix.
    pub fn new(w: Vec<f64>, clients: usize, n: usize) -> Self {
        assert_eq!(w.len(), clients * n, "matrix shape mismatch");
        assert!(w.iter().all(|&x| x >= 0.0), "benefits must be non-negative");
        Self { w, clients, n }
    }

    /// Random benefits in [0,1).
    pub fn random(clients: usize, n: usize, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        Self::new((0..clients * n).map(|_| rng.f64()).collect(), clients, n)
    }

    #[inline]
    fn benefit(&self, client: usize, facility: ElemId) -> f64 {
        self.w[client * self.n + facility as usize]
    }
}

impl Oracle for FacilityLocation {
    fn n(&self) -> usize {
        self.n
    }

    fn name(&self) -> &'static str {
        "facility-location"
    }

    fn new_state<'a>(&'a self, _view: Option<&[ElemId]>) -> Box<dyn GainState + 'a> {
        Box::new(FacState {
            oracle: self,
            best: vec![0.0; self.clients],
            solution: Vec::new(),
        })
    }

    fn elem_bytes(&self, _e: ElemId) -> usize {
        8 + 8 * self.clients // id + its benefit column
    }

    fn partitionable(&self) -> Option<&dyn Partitionable> {
        Some(self)
    }
}

impl Partitionable for FacilityLocation {
    fn extract_partition(&self, elems: &[ElemId]) -> PartitionPayload {
        // One benefit column per shipped facility; clients are a separate
        // axis, so every shard evaluates against all of them and the view
        // never matters.
        let mut columns = Vec::with_capacity(elems.len() * self.clients);
        for &e in elems {
            for c in 0..self.clients {
                columns.push(self.benefit(c, e));
            }
        }
        PartitionPayload {
            n_global: self.n,
            elems: elems.to_vec(),
            data: PartitionData::Facility { clients: self.clients, columns },
        }
    }
}

struct FacState<'a> {
    oracle: &'a FacilityLocation,
    /// Per-client best benefit under the current solution.
    best: Vec<f64>,
    solution: Vec<ElemId>,
}

impl GainState for FacState<'_> {
    fn value(&self) -> f64 {
        self.best.iter().sum()
    }

    fn gain(&self, e: ElemId) -> f64 {
        let mut acc = 0.0;
        for (c, &b) in self.best.iter().enumerate() {
            let w = self.oracle.benefit(c, e);
            if w > b {
                acc += w - b;
            }
        }
        acc
    }

    fn commit(&mut self, e: ElemId) {
        for (c, b) in self.best.iter_mut().enumerate() {
            let w = self.oracle.benefit(c, e);
            if w > *b {
                *b = w;
            }
        }
        self.solution.push(e);
    }

    fn solution(&self) -> &[ElemId] {
        &self.solution
    }

    fn call_cost(&self, _e: ElemId) -> u64 {
        self.oracle.clients as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::testutil;

    #[test]
    fn hand_values() {
        // 2 clients, 3 facilities.
        let o = FacilityLocation::new(vec![1.0, 0.5, 0.0, 0.0, 0.2, 0.9], 2, 3);
        assert_eq!(o.eval(&[]), 0.0);
        assert!((o.eval(&[0]) - 1.0).abs() < 1e-12);
        assert!((o.eval(&[0, 2]) - 1.9).abs() < 1e-12);
        assert!((o.eval(&[1]) - 0.7).abs() < 1e-12);
    }

    #[test]
    fn submodular_and_incremental() {
        let o = FacilityLocation::random(6, 8, 12);
        let mut rng = crate::util::rng::Rng::new(5);
        testutil::check_submodular(&o, &mut rng, 40);
        testutil::check_incremental(&o, &mut rng);
    }
}
