//! Table 4 — k-medoid exemplar clustering on 32 machines: relative function
//! value and speedup vs RandGreeDI across accumulation trees, under both
//! objective schemes (local-only and local + added images), plus the Fig. 7
//! exemplar-diversity readout and a CPU-vs-PJRT backend cross-check.
//!
//! Expected shape (§6.4): quality flat across (L, b) (within ~1.5% of
//! RandGreeDI); speedup grows as b shrinks because interior nodes hold
//! k·b elements instead of k·m and the k-medoid cost is quadratic in the
//! node's element count.

#[path = "harness.rs"]
mod harness;

use greedyml::algo::{run_greedyml, randgreedi::RandGreediOpts, DistConfig};
use greedyml::constraint::Cardinality;
use greedyml::data::gen::{gaussian_mixture, GaussianParams};
use greedyml::objective::{KMedoid, Oracle};
use greedyml::tree::AccumulationTree;
use std::sync::Arc;

/// Run a config twice and keep the faster BSP computation time (first-run
/// page-fault / thread-spawn noise is substantial at m=32 on shared CPUs).
fn timed_run(
    oracle: &dyn Oracle,
    constraint: &greedyml::constraint::Cardinality,
    cfg: &DistConfig,
) -> (greedyml::algo::DistOutcome, f64) {
    let a = run_greedyml(oracle, constraint, cfg).unwrap();
    let b = run_greedyml(oracle, constraint, cfg).unwrap();
    let secs = a.comp_secs.min(b.comp_secs);
    (b, secs)
}

fn main() {
    let n = 4096usize;
    let dim = 64usize; // matches the d64 AOT artifacts
    let (vs, labels) = gaussian_mixture(GaussianParams::tiny_imagenet_like(n, dim), 11);
    let vs = Arc::new(vs);
    let oracle = KMedoid::new(vs.clone());
    let k = 64usize;
    let m = 32u32;
    let constraint = Cardinality::new(k);
    println!("tiny-imagenet-like: n={n}, d={dim}, k={k}, m={m}");

    for added in [0usize, 256] {
        let variant = if added == 0 { "Local Obj." } else { "Added Images" };
        harness::section(&format!("Table 4 — {variant}"));
        let opts = RandGreediOpts {
            local_view: true,
            added_elements: added,
            ..RandGreediOpts::new(m, 3)
        };
        let rg_cfg = opts.to_config();
        let (rg, rg_time) = timed_run(&oracle, &constraint, &rg_cfg);
        let rg_global = oracle.eval(&rg.solution);
        println!("RandGreeDI baseline: global f = {rg_global:.4}, comp = {rg_time:.3}s");
        harness::row(
            &[4, 4, 14, 10, 14],
            &cells!["L", "b", "rel f (%)", "speedup", "interior |D|"],
        );
        for b in [2u32, 4, 8, 16] {
            let tree = AccumulationTree::new(m, b);
            let cfg = DistConfig {
                local_view: true,
                added_elements: added,
                ..DistConfig::greedyml(tree, 3)
            };
            let (out, secs) = timed_run(&oracle, &constraint, &cfg);
            let global = oracle.eval(&out.solution);
            harness::row(
                &[4, 4, 14, 10, 14],
                &cells![
                    tree.levels(),
                    b,
                    format!("{:.2}", 100.0 * global / rg_global),
                    format!("{:.2}", rg_time / secs.max(1e-9)),
                    out.max_accum_elems
                ],
            );
        }
    }

    // Fig. 7: exemplar diversity (labels are known for the synthetic mix).
    harness::section("Fig 7 — exemplar diversity");
    let cfg =
        DistConfig { local_view: true, ..DistConfig::greedyml(AccumulationTree::new(m, 2), 3) };
    let out = run_greedyml(&oracle, &constraint, &cfg).unwrap();
    let classes: std::collections::HashSet<u32> =
        out.solution.iter().map(|&e| labels[e as usize]).collect();
    let total = labels.iter().max().unwrap() + 1;
    println!(
        "GreedyML(b=2) exemplars: {} selected, spanning {}/{} classes",
        out.solution.len(),
        classes.len(),
        total
    );

    // Backend cross-check: the PJRT path must agree with the CPU oracle.
    if let Ok(engine) = greedyml::runtime::Engine::load(&greedyml::runtime::artifact_dir()) {
        harness::section("backend cross-check (CPU oracle vs AOT Pallas/PJRT)");
        let pjrt = greedyml::runtime::KMedoidPjrt::new(vs.clone(), Arc::new(engine)).unwrap();
        let tree = AccumulationTree::new(8, 2);
        let cpu_out = run_greedyml(
            &oracle,
            &constraint,
            &DistConfig { local_view: true, ..DistConfig::greedyml(tree, 3) },
        )
        .unwrap();
        let stat = harness::bench(0, 1, || {
            run_greedyml(
                &pjrt,
                &constraint,
                &DistConfig { local_view: true, ..DistConfig::greedyml(tree, 3) },
            )
            .unwrap()
        });
        let pjrt_out = run_greedyml(
            &pjrt,
            &constraint,
            &DistConfig { local_view: true, ..DistConfig::greedyml(tree, 3) },
        )
        .unwrap();
        let (g_cpu, g_pjrt) = (oracle.eval(&cpu_out.solution), oracle.eval(&pjrt_out.solution));
        println!(
            "global f: cpu {g_cpu:.4} vs pjrt {g_pjrt:.4} (agreement {:.2}%), pjrt wall {:.2}s",
            100.0 * g_pjrt / g_cpu,
            stat.median
        );
    } else {
        println!("(artifacts not built — run `make artifacts` for the PJRT cross-check)");
    }
}
