//! Table 1 — BSP complexity validation.
//!
//! Runs GreedyML/RandGreeDI on a synthetic k-cover workload across tree
//! shapes and compares *measured* quantities from the simulator against the
//! closed forms of Table 1 (rust/src/bsp.rs):
//!
//!   * elements per interior node  vs  k·⌈m^{1/L}⌉
//!   * calls per leaf node         vs  n·k/m   (naive GREEDY bound; Lazy
//!     Greedy sits well below — the ratio column shows how far)
//!   * communication volume        vs  δ·k·L·⌈m^{1/L}⌉
//!
//! Shape, not constants: PASS means within 4× of the prediction for the
//! bound-type rows and within 1.5× for exact-count rows.

#[path = "harness.rs"]
mod harness;

use greedyml::algo::{run_greedyml, DistConfig};
use greedyml::bsp::BspParams;
use greedyml::constraint::Cardinality;
use greedyml::data::gen::{transactions, TransactionParams};
use greedyml::greedy::GreedyKind;
use greedyml::objective::KCover;
use greedyml::tree::AccumulationTree;
use std::sync::Arc;

fn main() {
    let n = 40_000usize;
    let k = 120usize;
    let data = Arc::new(transactions(
        TransactionParams { num_sets: n, num_items: n, mean_size: 8.0, zipf_s: 0.8 },
        3,
    ));
    let delta = data.avg_set_size();
    let oracle = KCover::new(data);
    let constraint = Cardinality::new(k);

    // A problem spec equivalent to the oracle above, so the bench also
    // runs under `GREEDYML_BACKEND=process` (workers rebuild the dataset
    // from this and comm becomes measured instead of α–β-modeled).
    let problem_spec = format!(
        "dataset.kind = transactions\ndataset.n = {n}\ndataset.items = {n}\n\
         dataset.mean_size = 8.0\ndataset.zipf = 0.8\ndataset.seed = 3\nproblem.k = {k}\n"
    );

    harness::section(&format!(
        "Table 1: measured vs model (k-cover, n={n}, k={k}, delta={delta:.1})"
    ));
    harness::row(
        &[-14, 4, 4, 4, 14, 14, 8, 14, 14, 8],
        &cells![
            "algo",
            "m",
            "b",
            "L",
            "interior|D| meas",
            "model k*fanin",
            "check",
            "comm B meas",
            "model",
            "check"
        ],
    );

    let shapes = [(8u32, 8u32), (16, 16), (8, 2), (16, 4), (16, 2), (32, 2), (32, 8)];
    let mut outcomes = Vec::new();
    for (m, b) in shapes {
        let tree = AccumulationTree::new(m, b);
        let cfg = DistConfig {
            kind: GreedyKind::Naive, // Table 1 counts are for plain GREEDY
            problem: Some(problem_spec.clone()),
            ..DistConfig::greedyml(tree, 7)
        };
        let out = run_greedyml(&oracle, &constraint, &cfg).expect("run");
        let params = BspParams {
            n: n as u64,
            k: k as u64,
            m: m as u64,
            levels: tree.levels() as u64,
            delta,
        };
        let interior_model = params.interior_elems_greedyml() as f64;
        // Table 1's communication column is per *parent on the critical
        // path* (machine 0 receives at every level), not the tree-wide sum
        // (which is Θ(m·kδ) for every tree since each machine sends once).
        let comm_meas: u64 = out.machines[0].bytes_received;
        // Model comm is counted in elements·δ; convert to bytes (4 bytes per
        // id + per item) ≈ 4·(k·L·fanin·(δ+2)) — compare order only.
        let comm_model = 4.0 * (params.k * params.levels * params.fan_in()) as f64 * (delta + 2.0);
        let algo = if b >= m { "RandGreeDI" } else { "GreedyML" };
        harness::row(
            &[-14, 4, 4, 4, 14, 14, 8, 14, 14, 8],
            &cells![
                algo,
                m,
                b,
                tree.levels(),
                out.max_accum_elems,
                format!("{:.0}", interior_model),
                harness::shape_check(out.max_accum_elems as f64, interior_model, 1.5),
                comm_meas,
                format!("{:.0}", comm_model),
                harness::shape_check(comm_meas as f64, comm_model, 4.0)
            ],
        );
        outcomes.push((algo, m, b, tree, params, out));
    }

    // Makespan-vs-model cross-check: the measured end-to-end superstep
    // seconds (trace makespan) next to the BSP-modeled cost (measured
    // compute + α–β-modeled critical-path communication).  Under the
    // thread backend the comm column *is* the α–β model; under
    // `GREEDYML_BACKEND=process` it is measured pipe-transfer time, making
    // backend-measured comm directly comparable to the model.
    harness::section("makespan vs BSP model (measured superstep seconds vs modeled cost)");
    harness::row(
        &[-14, 4, 4, 12, 12, 12, 12, 10, 8],
        &cells![
            "algo",
            "m",
            "b",
            "makespan s",
            "comp s",
            "comm s",
            "comm model s",
            "comm",
            "check"
        ],
    );
    let alpha_beta = greedyml::dist::CommModel::default();
    for (algo, m, b, tree, params, out) in &outcomes {
        // Critical-path modeled comm: machine 0 gathers `fanin − 1`
        // messages of ≈ 4·k·(δ+2) bytes at each of L levels.
        let msgs_per_level = params.fan_in().saturating_sub(1);
        let msg_bytes = (4.0 * params.k as f64 * (delta + 2.0)) as u64;
        let comm_model_secs = tree.levels() as f64
            * alpha_beta.gather_time(&vec![msg_bytes; msgs_per_level as usize]);
        let model_secs = out.comp_secs + comm_model_secs;
        harness::row(
            &[-14, 4, 4, 12, 12, 12, 12, 10, 8],
            &cells![
                algo,
                m,
                b,
                format!("{:.6}", out.trace.makespan()),
                format!("{:.6}", out.comp_secs),
                format!("{:.6}", out.comm_secs),
                format!("{:.6}", comm_model_secs),
                if out.comm_measured { "measured" } else { "α–β" },
                harness::shape_check(out.trace.makespan(), model_secs, 2.0)
            ],
        );
    }

    harness::section("calls per leaf (naive GREEDY): measured vs n*k/m bound");
    harness::row(&[4, 4, 16, 16, 10], &cells!["m", "b", "max leaf calls", "bound nk/m", "check"]);
    for (m, b) in [(8u32, 2u32), (16, 4), (32, 2)] {
        let tree = AccumulationTree::new(m, b);
        let cfg = DistConfig {
            kind: GreedyKind::Naive,
            problem: Some(problem_spec.clone()),
            ..DistConfig::greedyml(tree, 7)
        };
        let out = run_greedyml(&oracle, &constraint, &cfg).expect("run");
        let leaf_calls = out.levels[0].max_calls as f64;
        let bound = (n * k / m as usize) as f64;
        harness::row(
            &[4, 4, 16, 16, 10],
            &cells![
                m,
                b,
                out.levels[0].max_calls,
                format!("{bound:.0}"),
                // Upper bound: PASS when measured ≤ ~1.2× bound (partition
                // imbalance) — early termination may push it far below.
                if leaf_calls <= 1.2 * bound { "PASS" } else { "WARN" }
            ],
        );
    }

    harness::section("multilevel advantage (the paper's core claim)");
    let rg = BspParams { n: n as u64, k: 20_000, m: 32, levels: 1, delta };
    let gml = BspParams { levels: 5, ..rg };
    println!(
        "for k=20k, m=32: RandGreeDI interior work k^2*m = {:.2e}, \
         GreedyML L*k^2*ceil(m^(1/L)) = {:.2e} ({}x less)",
        (rg.k * rg.k * rg.m) as f64,
        (gml.levels * gml.k * gml.k * gml.fan_in()) as f64,
        (rg.k * rg.k * rg.m) / (gml.levels * gml.k * gml.k * gml.fan_in())
    );
}
