//! Fig. 6 — strong scaling, RandGreeDI vs GreedyML(b=2), k = 50,
//! friendster-like RMAT graph, m = 8 … 128.
//!
//! Stacked bars in the paper → two columns here: computation seconds (BSP:
//! Σ per-level max) and communication seconds (α–β model).  Expected shape:
//! RandGreeDI's comm grows linearly in m (the root receives m−1 solutions
//! serially), GreedyML's grows ~logarithmically and stays flat; computation
//! scales similarly for both (leaf-dominated), with RandGreeDI slightly
//! worse at large m because the central accumulation has a k²m term.

#[path = "harness.rs"]
mod harness;

use greedyml::algo::{run_greedyml, DistConfig};
use greedyml::constraint::Cardinality;
use greedyml::data::gen::{rmat, RmatParams};
use greedyml::objective::KDominatingSet;
use greedyml::tree::AccumulationTree;
use std::sync::Arc;

fn main() {
    let g = Arc::new(rmat(RmatParams::friendster_like(16), 9));
    let oracle = KDominatingSet::new(g.clone());
    let k = 50usize;
    let constraint = Cardinality::new(k);
    println!(
        "friendster-like RMAT: n={}, avg degree {:.1}, k={k}",
        g.num_vertices(),
        g.avg_degree()
    );

    harness::row(
        &[6, -12, 4, 12, 12, 12, 14],
        &cells!["m", "algo", "L", "comp (s)", "comm (s)", "total (s)", "crit calls"],
    );
    let mut rg_comm = Vec::new();
    let mut gml_comm = Vec::new();
    for m in [8u32, 16, 32, 64, 128] {
        for (algo, b) in [("RandGreeDI", m), ("GreedyML", 2)] {
            let tree = AccumulationTree::new(m, b);
            let cfg = DistConfig {
                compare_all_children: algo == "RandGreeDI",
                ..DistConfig::greedyml(tree, 13)
            };
            let out = run_greedyml(&oracle, &constraint, &cfg).unwrap();
            harness::row(
                &[6, -12, 4, 12, 12, 12, 14],
                &cells![
                    m,
                    algo,
                    tree.levels(),
                    format!("{:.4}", out.comp_secs),
                    format!("{:.6}", out.comm_secs),
                    format!("{:.4}", out.total_secs()),
                    out.critical_calls
                ],
            );
            if algo == "RandGreeDI" {
                rg_comm.push(out.comm_secs);
            } else {
                gml_comm.push(out.comm_secs);
            }
        }
    }
    let rg_growth = rg_comm.last().unwrap() / rg_comm.first().unwrap();
    let gml_growth = gml_comm.last().unwrap() / gml_comm.first().unwrap();
    println!(
        "\ncomm growth m=8→128: RandGreeDI {rg_growth:.1}x (linear in m, damped by \
         shrinking per-leaf hub solutions), GreedyML {gml_growth:.1}x (logarithmic)"
    );
    // The claim under test is the *divergence*: RG comm must grow much
    // faster than GML comm as machines scale (Fig. 6's stacked bars).
    let divergence = rg_growth / gml_growth;
    println!(
        "divergence RG/GML = {divergence:.1}x — {}",
        if divergence >= 2.5 { "PASS" } else { "WARN" }
    );
}
