//! dist_ship: what it costs to get a problem onto remote workers —
//! spec shipping (rebuild recipe, every worker regenerates the whole
//! dataset) vs partition shipping (each worker receives only its O(n/m)
//! shard, solutions travel with their data).
//!
//! Reports, per mode: the Init payload wire bytes (what actually crosses
//! the pipe per worker), the per-worker dataset footprint (full rebuild
//! vs shard), the meter's per-worker peak, end-to-end wall time on the
//! process backend, and the shard/full ratio checked against the ideal
//! 1/m (the paper's whole premise, §1/§4.2: no machine holds the full
//! dataset).  A third dimension is the wire encoding (v5): the same
//! `init_part` frames are encoded under `--wire json` and `--wire
//! binary` and the byte ratio asserted at ≤ 45% for coverage shards —
//! the binary codec's compactness criterion lives here, the correctness
//! battery in `rust/tests/test_wire_binary.rs`.  Flags: `--json` writes
//! `BENCH_dist_ship.json`, `--tiny` shrinks sizes for the CI smoke
//! invocation.

#[path = "harness.rs"]
mod harness;

use greedyml::algo::{run_dist, run_dist_pooled, DistConfig, SessionPool};
use greedyml::coordinator::{build_problem, experiment::build_constraint, problem_spec};
use greedyml::dist::wire::{write_cmd, ToWorker};
use greedyml::dist::{BackendSpec, CoresetSpec, ShipSpec, WireMode, WireSpec};
use greedyml::tree::AccumulationTree;
use greedyml::util::config::Config;
use greedyml::util::json::Json;
use greedyml::util::rng::RandomTape;

fn main() {
    let tiny = harness::flag("--tiny");
    let (n, m, k) = if tiny { (400usize, 4u32, 8usize) } else { (8000, 8, 32) };
    let seed = 42u64;
    let spec_text = format!(
        "[dataset]\nkind = retail\nn = {n}\nseed = 2\n[problem]\nk = {k}\n"
    );
    let parsed = Config::parse(&spec_text).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let constraint = build_constraint(&parsed, problem.oracle.n()).unwrap().0;
    let oracle = problem.oracle.as_ref();
    let shipped_spec = problem_spec(&parsed);

    harness::section(&format!("dist_ship: retail n={n}, m={m}, k={k}"));

    // ---- payload accounting (what Init actually carries) ---------------
    let p = oracle.partitionable().expect("k-cover is partitionable");
    let full_bytes = p.extract_partition(&(0..n as u32).collect::<Vec<_>>()).wire_bytes();
    let parts = RandomTape::draw(n, m, seed).partition();
    let shard_bytes: Vec<usize> =
        parts.iter().map(|part| p.extract_partition(part).wire_bytes()).collect();
    let shard_max = shard_bytes.iter().copied().max().unwrap_or(0);
    let shard_mean = shard_bytes.iter().sum::<usize>() as f64 / shard_bytes.len() as f64;
    let ideal = full_bytes as f64 / m as f64;
    println!(
        "Init payload per worker: spec recipe {} B (+ full {} B dataset rebuilt in-worker)",
        shipped_spec.len(),
        full_bytes
    );
    println!(
        "                         partition shard mean {:.0} B / max {shard_max} B \
         (ideal n/m share {:.0} B) [{}]",
        shard_mean,
        ideal,
        harness::shape_check(shard_mean, ideal, 2.0)
    );

    // ---- wire encoding (v5): binary vs json init_part frames ------------
    // The exact frames the coordinator puts on the wire under partition
    // shipping — envelope plus shard, one per worker, through the same
    // `write_cmd` the backends use.  The ≤ 45% bound for coverage shards
    // is the binary codec's compactness criterion; the codec test suite
    // checks correctness, not size, so the gate lives here.
    let init_frames = |mode: WireMode| -> usize {
        parts
            .iter()
            .enumerate()
            .map(|(i, part)| {
                let init = ToWorker::InitPart {
                    session: 1,
                    machine: i as u32,
                    threads: 1,
                    payload: p.extract_partition(part),
                };
                let mut buf = Vec::new();
                write_cmd(&mut buf, &init, mode).expect("encode init_part");
                buf.len()
            })
            .sum()
    };
    let json_wire_bytes = init_frames(WireMode::Json);
    let binary_wire_bytes = init_frames(WireMode::Binary);
    let wire_ratio = binary_wire_bytes as f64 / json_wire_bytes as f64;
    println!(
        "init_part frames, all {m} workers: json {json_wire_bytes} B, \
         binary {binary_wire_bytes} B (ratio {wire_ratio:.2})"
    );
    assert!(
        wire_ratio <= 0.45,
        "binary init_part frames must stay at or under 45% of json for coverage \
         shards, got {wire_ratio:.3}"
    );

    // ---- end-to-end wall time on the process backend --------------------
    let base = DistConfig {
        problem: Some(shipped_spec.clone()),
        worker_bin: Some(env!("CARGO_BIN_EXE_greedyml").to_string()),
        ..DistConfig::greedyml(AccumulationTree::new(m, 2), seed)
    };
    let (warmup, samples) = if tiny { (0, 2) } else { (1, 5) };
    let mut outcomes = Vec::new();
    let mut measure = |label: &str, cfg: DistConfig| {
        let stat = harness::bench(warmup, samples, || {
            let out = run_dist(oracle, constraint.as_ref(), &cfg).expect(label);
            outcomes.push((label.to_string(), out.value, out.peak_mem()));
        });
        println!("{label:>22}: {:.4}s median ({} samples)", stat.median, stat.samples);
        stat
    };
    let t_thread =
        measure("thread", DistConfig { backend: BackendSpec::Thread, ..base.clone() });
    let t_spec = measure(
        "process --ship spec",
        DistConfig { backend: BackendSpec::Process, ship: ShipSpec::Spec, ..base.clone() },
    );
    let t_part = measure(
        "process --ship part",
        DistConfig {
            backend: BackendSpec::Process,
            ship: ShipSpec::Partition,
            ..base.clone()
        },
    );
    let t_bin = measure(
        "process --wire binary",
        DistConfig {
            backend: BackendSpec::Process,
            ship: ShipSpec::Partition,
            wire: WireSpec::Binary,
            ..base.clone()
        },
    );

    // Every mode must have computed the same objective (bit-parity is the
    // test suite's job; the bench still refuses to report nonsense).
    let value0 = outcomes[0].1;
    assert!(
        outcomes.iter().all(|(_, v, _)| v.to_bits() == value0.to_bits()),
        "ship modes disagree on f(S): {outcomes:?}"
    );
    let peak_mem = outcomes.iter().map(|&(_, _, p)| p).max().unwrap_or(0);
    println!("objective {value0:.3}, per-worker peak {peak_mem} B (meter, mode-invariant)");

    // ---- warm vs cold: one resident fleet answering five jobs -----------
    // The resident-shard session premise in numbers: five (k, same-seed)
    // queries against one dataset, partition-shipped on the process
    // backend.  Warm = one SessionPool kept across jobs (shards ship at
    // establish, never again); cold = the pool cleared before every job
    // (each job pays a full fleet spawn + shard shipping).  Every job is
    // asserted bit-identical warm vs cold vs thread.
    harness::section("warm vs cold: one resident fleet answering 5 jobs");
    let job_ks: [usize; 5] = [4, 6, 8, 10, 12];
    let run_job = |k: usize, pool: &SessionPool| -> (f64, f64) {
        let spec = format!("{shipped_spec}problem.k = {k}\n");
        let spec_cfg = Config::parse(&spec).unwrap();
        let c = build_constraint(&spec_cfg, n).unwrap().0;
        let cfg = DistConfig {
            backend: BackendSpec::Process,
            ship: ShipSpec::Partition,
            problem: Some(spec),
            ..base.clone()
        };
        let t0 = std::time::Instant::now();
        let out = run_dist_pooled(oracle, c.as_ref(), &cfg, pool).expect("pooled job");
        (t0.elapsed().as_secs_f64(), out.value)
    };

    let warm_pool = SessionPool::new();
    let warm: Vec<(f64, f64)> = job_ks.iter().map(|&k| run_job(k, &warm_pool)).collect();
    let warm_init = warm_pool.init_bytes_total();
    assert_eq!(warm_pool.sessions_established(), 1, "one fleet must answer all 5 jobs");
    assert_eq!(warm_pool.warm_jobs(), job_ks.len() as u64 - 1);

    let cold_pool = SessionPool::new();
    let cold: Vec<(f64, f64)> = job_ks
        .iter()
        .map(|&k| {
            cold_pool.clear();
            run_job(k, &cold_pool)
        })
        .collect();
    let cold_init = cold_pool.init_bytes_total();
    assert_eq!(
        warm_init * job_ks.len() as u64,
        cold_init,
        "a warm fleet ships each partition shard exactly once; cold ships per job"
    );

    println!("{:>4} {:>12} {:>12}", "k", "warm secs", "cold secs");
    for (i, &k) in job_ks.iter().enumerate() {
        let spec = format!("{shipped_spec}problem.k = {k}\n");
        let spec_cfg = Config::parse(&spec).unwrap();
        let c = build_constraint(&spec_cfg, n).unwrap().0;
        let thread_cfg = DistConfig {
            backend: BackendSpec::Thread,
            problem: Some(spec),
            ..base.clone()
        };
        let t = run_dist(oracle, c.as_ref(), &thread_cfg).expect("thread job");
        assert_eq!(warm[i].1.to_bits(), cold[i].1.to_bits(), "k={k}: warm vs cold");
        assert_eq!(warm[i].1.to_bits(), t.value.to_bits(), "k={k}: warm vs thread");
        println!("{k:>4} {:>12.4} {:>12.4}", warm[i].0, cold[i].0);
    }
    let warm_secs_mean = warm.iter().map(|j| j.0).sum::<f64>() / warm.len() as f64;
    let cold_secs_mean = cold.iter().map(|j| j.0).sum::<f64>() / cold.len() as f64;
    println!(
        "Init bytes over 5 jobs: warm fleet {warm_init} B (shipped once), \
         cold fleets {cold_init} B ({}×)",
        job_ks.len()
    );

    // ---- coreset mode: what moves through the accumulation tree ---------
    // The streaming premise (docs/streaming.md): in coreset mode every
    // message up the tree is a sieve coreset — a subset of the sender's
    // input — so total accumulation bytes must come in strictly below
    // full-shard shipping (moving whole O(n/m) shards through the tree),
    // and the meter's leaf charge drops from the shard to the coreset.
    harness::section("coreset vs full: accumulation bytes and peak memory");
    let accum_bytes = |out: &greedyml::algo::DistOutcome| -> u64 {
        out.machines.iter().map(|s| s.bytes_sent).sum()
    };
    let full_run = run_dist(
        oracle,
        constraint.as_ref(),
        &DistConfig { backend: BackendSpec::Thread, ..base.clone() },
    )
    .expect("full-mode run");
    let coreset_run = run_dist(
        oracle,
        constraint.as_ref(),
        &DistConfig { backend: BackendSpec::Thread, coreset: CoresetSpec::On, ..base.clone() },
    )
    .expect("coreset-mode run");
    let shard_total: usize = shard_bytes.iter().sum();
    let accum_full = accum_bytes(&full_run);
    let accum_coreset = accum_bytes(&coreset_run);
    let accum_over_shard = accum_coreset as f64 / shard_total as f64;
    println!(
        "accumulation bytes: full-shard shipping {shard_total} B, coreset {accum_coreset} B \
         (ratio {accum_over_shard:.3}); full-mode solution shipping {accum_full} B"
    );
    println!(
        "per-worker peak:    full {} B, coreset {} B; value full {:.3}, coreset {:.3}",
        full_run.peak_mem(),
        coreset_run.peak_mem(),
        full_run.value,
        coreset_run.value
    );
    assert!(
        (accum_coreset as usize) < shard_total,
        "coreset accumulation bytes ({accum_coreset}) must be strictly below full-shard \
         shipping ({shard_total})"
    );
    assert!(
        coreset_run.peak_mem() <= full_run.peak_mem(),
        "coreset peak mem {} exceeds full-run peak {}",
        coreset_run.peak_mem(),
        full_run.peak_mem()
    );
    assert!(
        coreset_run.value >= 0.4 * full_run.value,
        "coreset value {} fell out of the sieve band of {}",
        coreset_run.value,
        full_run.value
    );

    if harness::flag("--json") {
        let doc = Json::obj([
            ("bench", Json::Str("dist_ship".to_string())),
            ("n", Json::Num(n as f64)),
            ("machines", Json::Num(m as f64)),
            ("k", Json::Num(k as f64)),
            ("spec_recipe_bytes", Json::Num(shipped_spec.len() as f64)),
            ("spec_worker_data_bytes", Json::Num(full_bytes as f64)),
            ("partition_shard_bytes_mean", Json::Num(shard_mean)),
            ("partition_shard_bytes_max", Json::Num(shard_max as f64)),
            ("shard_over_full_ratio", Json::Num(shard_mean / full_bytes as f64)),
            ("ideal_ratio", Json::Num(1.0 / m as f64)),
            ("init_json_wire_bytes", Json::Num(json_wire_bytes as f64)),
            ("init_binary_wire_bytes", Json::Num(binary_wire_bytes as f64)),
            ("binary_over_json_wire_ratio", Json::Num(wire_ratio)),
            ("peak_mem_bytes", Json::Num(peak_mem as f64)),
            ("value", Json::Num(value0)),
            ("thread_median_secs", Json::Num(t_thread.median)),
            ("spec_median_secs", Json::Num(t_spec.median)),
            ("partition_median_secs", Json::Num(t_part.median)),
            ("binary_median_secs", Json::Num(t_bin.median)),
            ("warm_fleet_jobs", Json::Num(job_ks.len() as f64)),
            ("warm_init_bytes", Json::Num(warm_init as f64)),
            ("cold_init_bytes", Json::Num(cold_init as f64)),
            ("warm_job_secs_mean", Json::Num(warm_secs_mean)),
            ("cold_job_secs_mean", Json::Num(cold_secs_mean)),
            ("full_shard_ship_bytes", Json::Num(shard_total as f64)),
            ("accum_full_mode_bytes", Json::Num(accum_full as f64)),
            ("accum_coreset_bytes", Json::Num(accum_coreset as f64)),
            ("accum_coreset_over_shard_ratio", Json::Num(accum_over_shard)),
            ("coreset_peak_mem_bytes", Json::Num(coreset_run.peak_mem() as f64)),
            ("coreset_value", Json::Num(coreset_run.value)),
        ]);
        let path = "BENCH_dist_ship.json";
        std::fs::write(path, doc.to_pretty()).expect("write bench json");
        println!("wrote {path}");
    }
}
