//! §Perf microbenchmarks: the hot paths identified in EXPERIMENTS.md §Perf.
//!
//!   P1.  k-medoid CPU gain_batch      (tiled norm-trick kernel, serial)
//!   P1p. same scan fanned out         (par_gain_batch on the executor)
//!   P1b. k-medoid commit path         (fused kernel + cached norms)
//!   P2.  coverage union_gain_sparse   (bitset probes)
//!   P3.  coverage union_gain (dense)  (word-wise popcount)
//!   P4.  lazy greedy end-to-end       (heap + dedup + gains, threads = 1)
//!   P4t. lazy greedy end-to-end       (threads = default_threads)
//!   P5.  PJRT k-medoid gain_batch     (kernel-launch amortization)
//!
//! Run before/after every optimization; EXPERIMENTS.md §Perf records the
//! iteration log.  Flags: `--json` writes `BENCH_perf_micro.json`
//! (machine-readable medians + throughputs), `--tiny` shrinks every size
//! for the CI smoke invocation.

#[path = "harness.rs"]
mod harness;

use greedyml::constraint::Cardinality;
use greedyml::data::gen;
use greedyml::dist::pool;
use greedyml::greedy::greedy_lazy;
use greedyml::objective::{KCover, KMedoid, Oracle};
use greedyml::util::bitset::BitSet;
use std::sync::Arc;

fn main() {
    let tiny = harness::flag("--tiny");
    let mut report = harness::JsonReport::new("perf_micro");

    // P1: k-medoid gains through the tiled kernel.  (Tiny keeps ncand >
    // GAIN_CHUNK so the P1p smoke still goes through the executor fan-out
    // rather than the single-chunk serial fallback.)
    let (n, dim, ncand) = if tiny { (256, 32, 128) } else { (2048, 128, 512) };
    let (vs, _) = gen::gaussian_mixture(
        gen::GaussianParams { n, dim, classes: 8, noise: 0.3 },
        3,
    );
    let oracle = KMedoid::new(Arc::new(vs));
    let st = oracle.new_state(None);
    let cands: Vec<u32> = (0..ncand as u32).collect();
    let mut out = Vec::new();
    let s = harness::bench(1, 5, || st.gain_batch(&cands, &mut out));
    println!(
        "P1 kmedoid cpu gain_batch ({n}x{dim} view, {ncand} cands): {:.4}s median -> {:.0} gains/s",
        s.median,
        ncand as f64 / s.median
    );
    report.record("P1", s, Some(ncand as f64 / s.median));

    // P1p: the same scan fanned out over the two-level executor.
    let threads = pool::default_threads();
    let s = pool::with_pool(threads, |_| {
        harness::bench(1, 5, || pool::par_gain_batch(&*st, &cands, &mut out))
    });
    println!(
        "P1p kmedoid par_gain_batch ({threads} threads): {:.4}s median -> {:.0} gains/s",
        s.median,
        ncand as f64 / s.median
    );
    report.record("P1p", s, Some(ncand as f64 / s.median));

    // P1b: commit path (mind update, incl. state init).
    let commits: Vec<u32> = (0..4).map(|i| (i * n as u32 / 4 + 1).min(n as u32 - 1)).collect();
    let s = harness::bench(1, 5, || {
        let mut st = oracle.new_state(None);
        for &e in &commits {
            st.commit(e);
        }
    });
    println!("P1b kmedoid commit x4 (incl. state init): {:.4}s median", s.median);
    report.record("P1b", s, None);

    // P2/P3: coverage gains.
    let (nsets, nitems) = if tiny { (3_000, 6_000) } else { (30_000, 60_000) };
    let data = Arc::new(gen::transactions(
        gen::TransactionParams { num_sets: nsets, num_items: nitems, mean_size: 20.0, zipf_s: 0.9 },
        7,
    ));
    let cov = KCover::new(data.clone());
    let mut cst = cov.new_state(None);
    for e in (0..nsets as u32).step_by(100) {
        cst.commit(e);
    }
    let cands: Vec<u32> = (0..nsets as u32).collect();
    let s = harness::bench(1, 5, || cst.gain_batch(&cands, &mut out));
    println!(
        "P2 coverage gain_batch sparse ({nsets} cands, avg delta 20): {:.4}s -> {:.1}M gains/s",
        s.median,
        nsets as f64 / s.median / 1e6
    );
    report.record("P2", s, Some(nsets as f64 / s.median));

    let bits = if tiny { 1 << 16 } else { 1 << 20 };
    let a = BitSet::from_iter(bits, (0..bits).step_by(3));
    let b = BitSet::from_iter(bits, (0..bits).step_by(5));
    let s = harness::bench(1, 20, || a.union_gain(&b));
    println!(
        "P3 dense union_gain over {}-bit universes: {:.6}s -> {:.1} GB/s word scan",
        bits,
        s.median,
        (2.0 * bits as f64 / 8.0) / s.median / 1e9
    );
    report.record("P3", s, Some(bits as f64 / s.median));

    // P4/P4t: lazy greedy end-to-end on coverage, serial vs fanned out.
    let k = if tiny { 16 } else { 100 };
    let c = Cardinality::new(k);
    let s = pool::with_pool(1, |_| harness::bench(1, 3, || greedy_lazy(&cov, &c, &cands, None)));
    println!("P4 lazy greedy (n={nsets}, k={k}, threads=1): {:.4}s median", s.median);
    report.record("P4", s, None);
    let s = pool::with_pool(threads, |_| {
        harness::bench(1, 3, || greedy_lazy(&cov, &c, &cands, None))
    });
    println!("P4t lazy greedy (n={nsets}, k={k}, threads={threads}): {:.4}s median", s.median);
    report.record("P4t", s, None);

    // P5: PJRT kernel path.
    if let Ok(engine) = greedyml::runtime::Engine::load(&greedyml::runtime::artifact_dir()) {
        let (vs, _) = gen::gaussian_mixture(
            gen::GaussianParams { n, dim, classes: 8, noise: 0.3 },
            3,
        );
        let pjrt =
            greedyml::runtime::KMedoidPjrt::new(Arc::new(vs), Arc::new(engine)).unwrap();
        let st = pjrt.new_state(None);
        let cands: Vec<u32> = (0..ncand as u32).collect();
        let s = harness::bench(1, 5, || st.gain_batch(&cands, &mut out));
        println!(
            "P5 kmedoid pjrt gain_batch ({n}x{dim}, {ncand} cands): {:.4}s -> {:.0} gains/s",
            s.median,
            ncand as f64 / s.median
        );
        report.record("P5", s, Some(ncand as f64 / s.median));
    }

    if harness::flag("--json") {
        let path = report.default_path();
        report.write(&path).expect("write bench JSON");
        println!("wrote {path}");
    }
}
