//! §Perf microbenchmarks: the hot paths identified in EXPERIMENTS.md §Perf.
//!
//!   P1. k-medoid CPU gain_batch       (dense float distance loop)
//!   P2. coverage union_gain_sparse    (bitset probes)
//!   P3. coverage union_gain (dense)   (word-wise popcount)
//!   P4. lazy greedy end-to-end        (heap + dedup + gains)
//!   P5. PJRT k-medoid gain_batch      (kernel-launch amortization)
//!
//! Run before/after every optimization; EXPERIMENTS.md §Perf records the
//! iteration log.

#[path = "harness.rs"]
mod harness;

use greedyml::constraint::Cardinality;
use greedyml::data::gen;
use greedyml::greedy::greedy_lazy;
use greedyml::objective::{KCover, KMedoid, Oracle};
use greedyml::util::bitset::BitSet;
use std::sync::Arc;

fn main() {
    // P1: k-medoid gains.
    let (vs, _) = gen::gaussian_mixture(
        gen::GaussianParams { n: 2048, dim: 128, classes: 8, noise: 0.3 },
        3,
    );
    let oracle = KMedoid::new(Arc::new(vs));
    let st = oracle.new_state(None);
    let cands: Vec<u32> = (0..512).collect();
    let mut out = Vec::new();
    let s = harness::bench(1, 5, || st.gain_batch(&cands, &mut out));
    println!(
        "P1 kmedoid cpu gain_batch (2048x128 view, 512 cands): {:.4}s median -> {:.0} gains/s",
        s.median,
        512.0 / s.median
    );
    // Commit path (mind update).
    let s = harness::bench(1, 5, || {
        let mut st = oracle.new_state(None);
        for e in [1u32, 500, 1000, 1500] {
            st.commit(e);
        }
    });
    println!("P1b kmedoid commit x4 (incl. state init): {:.4}s median", s.median);

    // P2/P3: coverage gains.
    let data = Arc::new(gen::transactions(
        gen::TransactionParams { num_sets: 30_000, num_items: 60_000, mean_size: 20.0, zipf_s: 0.9 },
        7,
    ));
    let cov = KCover::new(data.clone());
    let mut cst = cov.new_state(None);
    for e in (0..30_000).step_by(100) {
        cst.commit(e);
    }
    let cands: Vec<u32> = (0..30_000).collect();
    let s = harness::bench(1, 5, || cst.gain_batch(&cands, &mut out));
    println!(
        "P2 coverage gain_batch sparse (30k cands, avg delta 20): {:.4}s -> {:.1}M gains/s",
        s.median,
        30_000.0 / s.median / 1e6
    );
    let a = BitSet::from_iter(1 << 20, (0..1 << 20).step_by(3));
    let b = BitSet::from_iter(1 << 20, (0..1 << 20).step_by(5));
    let s = harness::bench(1, 20, || a.union_gain(&b));
    println!(
        "P3 dense union_gain over 1M-bit universes: {:.6}s -> {:.1} GB/s word scan",
        s.median,
        (2.0 * (1 << 20) as f64 / 8.0) / s.median / 1e9
    );

    // P4: lazy greedy end-to-end on coverage.
    let c = Cardinality::new(100);
    let s = harness::bench(1, 3, || greedy_lazy(&cov, &c, &cands, None));
    println!("P4 lazy greedy (n=30k, k=100): {:.4}s median", s.median);

    // P5: PJRT kernel path.
    if let Ok(engine) = greedyml::runtime::Engine::load(&greedyml::runtime::artifact_dir()) {
        let (vs, _) = gen::gaussian_mixture(
            gen::GaussianParams { n: 2048, dim: 128, classes: 8, noise: 0.3 },
            3,
        );
        let pjrt =
            greedyml::runtime::KMedoidPjrt::new(Arc::new(vs), Arc::new(engine)).unwrap();
        let st = pjrt.new_state(None);
        let cands: Vec<u32> = (0..512).collect();
        let s = harness::bench(1, 5, || st.gain_batch(&cands, &mut out));
        println!(
            "P5 kmedoid pjrt gain_batch (2048x128, 512 cands): {:.4}s -> {:.0} gains/s",
            s.median,
            512.0 / s.median
        );
    }
}
