//! Table 3 — fixed k, shrinking per-machine memory, three machine
//! organizations: RG(m=8, b=8) at the full limit, GML(m=16, b=4, L=2) at
//! half, GML(m=32, b=2, L=5) at a quarter.  Datasets: friendster-like
//! (RMAT), road-like, webdocs-like (the paper's trio).
//!
//! Expected: all three succeed at their respective limits (RandGreeDI
//! *cannot* run at the smaller ones — verified as real OOM), relative
//! function values within a fraction of a percent of each other, execution
//! time growing with tree depth (§6.2.2).

#[path = "harness.rs"]
mod harness;

use greedyml::algo::{run_greedyml, DistConfig};
use greedyml::constraint::Cardinality;
use greedyml::data::gen;
use greedyml::greedy::GreedyKind;
use greedyml::objective::{KCover, KDominatingSet, Oracle};
use greedyml::tree::AccumulationTree;
use greedyml::util::fmt_bytes;
use std::sync::Arc;

fn main() {
    let sets: Vec<(&str, Arc<dyn Oracle>, usize)> = vec![
        (
            "friendster-like",
            Arc::new(KDominatingSet::new(Arc::new(gen::rmat(
                gen::RmatParams::friendster_like(14),
                1,
            )))),
            600,
        ),
        (
            "road-usa-like",
            Arc::new(KDominatingSet::new(Arc::new(gen::road(
                gen::RoadParams::usa_like(1 << 15),
                2,
            )))),
            600,
        ),
        (
            "webdocs-like",
            Arc::new(KCover::new(Arc::new(gen::transactions(
                gen::TransactionParams {
                    num_sets: 4000,
                    num_items: 16_000,
                    mean_size: 177.2,
                    zipf_s: 1.0,
                },
                3,
            )))),
            300,
        ),
    ];

    harness::row(
        &[-16, -6, 10, 4, 4, 4, 14, 12, 12],
        &cells!["dataset", "alg", "mem limit", "m", "b", "L", "f(S)", "rel f(%)", "time (s)"],
    );

    for (name, oracle, k) in sets {
        let constraint = Cardinality::new(k);
        // Probe each machine organization unlimited to find its true peak,
        // then run it again with a limit just above that peak (memory
        // enforcement on) — mirroring how the paper sizes 4 GB / 2 GB / 1 GB
        // to each configuration's accumulation footprint.
        let configs: [(&str, u32, u32); 3] = [("RG", 8, 8), ("GML", 16, 4), ("GML", 32, 2)];
        let mut baseline = None;
        let mut limits = Vec::new();
        for (alg, m, b) in configs {
            let tree = AccumulationTree::new(m, b);
            let mk_cfg = |limit: Option<u64>| DistConfig {
                mem_limit: limit,
                kind: GreedyKind::Lazy,
                compare_all_children: alg == "RG",
                ..DistConfig::greedyml(tree, 4)
            };
            let probe = run_greedyml(oracle.as_ref(), &constraint, &mk_cfg(None)).unwrap();
            let limit = (probe.peak_mem() as f64 * 1.1) as u64;
            limits.push(limit);
            let out = run_greedyml(oracle.as_ref(), &constraint, &mk_cfg(Some(limit))).unwrap();
            let base = *baseline.get_or_insert(out.value);
            harness::row(
                &[-16, -6, 10, 4, 4, 4, 14, 12, 12],
                &cells![
                    name,
                    alg,
                    fmt_bytes(limit),
                    m,
                    b,
                    tree.levels(),
                    format!("{:.0}", out.value),
                    format!("{:.3}", 100.0 * out.value / base),
                    format!("{:.3}", out.total_secs())
                ],
            );
        }
        // The paper's point: RandGreeDI cannot run inside the budget the
        // deepest GreedyML tree needs.
        let tight = *limits.last().unwrap();
        let rg_tight = DistConfig {
            mem_limit: Some(tight),
            compare_all_children: true,
            ..DistConfig::greedyml(AccumulationTree::randgreedi(8), 4)
        };
        match run_greedyml(oracle.as_ref(), &constraint, &rg_tight) {
            Err(_) => println!(
                "  [check] RG(m=8) at the GML(32,2) budget {} OOMs as expected",
                fmt_bytes(tight)
            ),
            Ok(_) => println!("  [check] WARN: RG(m=8) unexpectedly fit at {}", fmt_bytes(tight)),
        }
    }
    println!(
        "\nexpected: per dataset, the three rows agree on f(S) to well under 1%, \
         while time grows with L (communication + synchronization), §6.2.2 Table 3."
    );
}
