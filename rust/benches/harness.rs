//! Shared bench harness (criterion is unavailable offline — DESIGN.md §2).
//!
//! Provides robust wall-clock measurement (warmup + N samples, median /
//! min / stddev), fixed-width table printing, and the experiment-wide
//! convention of reporting geometric means across datasets (§6: the paper
//! reports geomeans of six repetitions).
//!
//! Every bench binary is `harness = false` and regenerates one table or
//! figure from the paper; `cargo bench` runs them all and
//! `bench_output.txt` is the evidence trail referenced by EXPERIMENTS.md.

#![allow(dead_code)] // each bench uses a subset of the harness

use std::time::Instant;

/// Summary of repeated timings (seconds).
#[derive(Clone, Copy, Debug)]
pub struct BenchStat {
    pub median: f64,
    pub mean: f64,
    pub min: f64,
    pub stddev: f64,
    pub samples: usize,
}

/// Time `f` with `warmup` throwaway runs and `samples` measured runs.
pub fn bench<R>(warmup: usize, samples: usize, mut f: impl FnMut() -> R) -> BenchStat {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    BenchStat {
        median: greedyml::util::stats::median(&times),
        mean: greedyml::util::stats::mean(&times),
        min: greedyml::util::stats::min(&times),
        stddev: greedyml::util::stats::stddev(&times),
        samples,
    }
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Print one formatted row from already-stringified cells with the given
/// column widths (negative width = left align).
pub fn row(widths: &[i32], cells: &[String]) {
    let mut line = String::new();
    for (w, c) in widths.iter().zip(cells) {
        if *w < 0 {
            line.push_str(&format!("{:<width$} ", c, width = (-w) as usize));
        } else {
            line.push_str(&format!("{:>width$} ", c, width = *w as usize));
        }
    }
    println!("{}", line.trim_end());
}

/// Convenience: stringify heterogeneous cells.
#[macro_export]
macro_rules! cells {
    ($($x:expr),* $(,)?) => { vec![$(format!("{}", $x)),*] };
}

/// Geometric mean (re-exported for benches).
pub fn geomean(xs: &[f64]) -> f64 {
    greedyml::util::stats::geomean(xs)
}

/// True when the given flag (e.g. `--json`, `--tiny`) was passed to the
/// bench binary (`cargo bench --bench <name> -- --json`).
pub fn flag(name: &str) -> bool {
    std::env::args().any(|a| a == name)
}

/// Machine-readable bench output (the `--json` mode): one entry per
/// measured point, written as `BENCH_<bench>.json` so the perf trajectory
/// is diffable across PRs (EXPERIMENTS.md §Perf references these files).
pub struct JsonReport {
    bench: String,
    entries: Vec<(String, BenchStat, Option<f64>)>,
}

impl JsonReport {
    /// Start a report for the named bench.
    pub fn new(bench: &str) -> Self {
        Self { bench: bench.to_string(), entries: Vec::new() }
    }

    /// Record one measured point; `throughput` is items/second where the
    /// bench has a natural unit (gains/s, rows/s), `None` otherwise.
    pub fn record(&mut self, key: &str, stat: BenchStat, throughput: Option<f64>) {
        self.entries.push((key.to_string(), stat, throughput));
    }

    /// Default output path for this bench (working directory).
    pub fn default_path(&self) -> String {
        format!("BENCH_{}.json", self.bench)
    }

    /// Write the report as deterministic pretty JSON.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        use greedyml::util::json::Json;
        use std::collections::BTreeMap;
        let mut entries = BTreeMap::new();
        for (key, stat, thr) in &self.entries {
            let mut obj = BTreeMap::new();
            obj.insert("median_secs".to_string(), Json::Num(stat.median));
            obj.insert("min_secs".to_string(), Json::Num(stat.min));
            obj.insert("stddev_secs".to_string(), Json::Num(stat.stddev));
            obj.insert("samples".to_string(), Json::Num(stat.samples as f64));
            if let Some(t) = thr {
                obj.insert("throughput_per_sec".to_string(), Json::Num(*t));
            }
            entries.insert(key.clone(), Json::Obj(obj));
        }
        let doc = Json::Obj(
            [
                ("bench".to_string(), Json::Str(self.bench.clone())),
                ("entries".to_string(), Json::Obj(entries)),
            ]
            .into_iter()
            .collect(),
        );
        std::fs::write(path, doc.to_pretty())
    }
}

/// Check an observed/predicted ratio against a tolerance band and render a
/// PASS/soft-FAIL marker (benches validate shape, not constants).
pub fn shape_check(observed: f64, predicted: f64, tol_ratio: f64) -> &'static str {
    if predicted <= 0.0 {
        return "n/a";
    }
    let r = observed / predicted;
    if r >= 1.0 / tol_ratio && r <= tol_ratio {
        "PASS"
    } else {
        "WARN"
    }
}
