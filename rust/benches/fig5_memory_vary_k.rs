//! Fig. 5 — varying k under a hard per-machine memory limit (16 machines).
//!
//! The paper's §6.2.1: road_usa, 100 MB per machine, k from 128k to 1,024k.
//! Only the smallest k fits RandGreeDI; for larger k the lowest-depth
//! accumulation tree that fits is selected (the (L, b) annotation on each
//! bar).  Scaled here: road-like graph, proportional limit, k sweep chosen
//! so the same fits/doesn't-fit ladder appears.
//!
//! Left plot → "calls" columns (critical path vs sequential GREEDY).
//! Right plot → "rel f(%)" column (quality vs GREEDY; paper: within 6%).

#[path = "harness.rs"]
mod harness;

use greedyml::algo::{run_greedyml, run_sequential, DistConfig};
use greedyml::constraint::Cardinality;
use greedyml::data::gen::{road, RoadParams};
use greedyml::greedy::GreedyKind;
use greedyml::objective::KDominatingSet;
use greedyml::tree::AccumulationTree;
use greedyml::util::fmt_bytes;
use std::sync::Arc;

fn main() {
    let m = 16u32;
    let g = Arc::new(road(RoadParams::usa_like(1 << 16), 5));
    let oracle = KDominatingSet::new(g.clone());
    // Scale the paper's 100 MB so the leaf partitions fit with headroom but
    // wide accumulations do not: leaves hold ~n/m ≈ 4096 elements (~80 KiB
    // at δ̄ ≈ 2.4); the ladder is then set by the accumulation term b·k·e̅.
    let limit = 600 * 1024u64;
    println!(
        "road-like n={}, m={m}, per-machine limit {}",
        g.num_vertices(),
        fmt_bytes(limit)
    );

    harness::row(
        &[8, 12, 8, 16, 16, 12, 10],
        &cells!["k", "algo", "(L,b)", "crit calls", "greedy calls", "rel f(%)", "peak mem"],
    );

    for k in [500usize, 1000, 2000, 4000, 8000] {
        let constraint = Cardinality::new(k);
        let seq = run_sequential(&oracle, &constraint, GreedyKind::Lazy, None).unwrap();
        // RandGreeDI attempt (b = m) then lowest-depth fitting tree.
        let mut chosen = None;
        for b in [m, 8, 4, 2] {
            let tree = AccumulationTree::new(m, b);
            let cfg = DistConfig { mem_limit: Some(limit), ..DistConfig::greedyml(tree, 11) };
            match run_greedyml(&oracle, &constraint, &cfg) {
                Ok(out) => {
                    chosen = Some((b, tree.levels(), out));
                    break;
                }
                Err(_) if b == m => {
                    // Record that RandGreeDI OOMed for this k.
                    harness::row(
                        &[8, 12, 8, 16, 16, 12, 10],
                        &cells![k, "RandGreeDI", format!("(1,{m})"), "OOM", "-", "-", "-"],
                    );
                }
                Err(_) => {}
            }
        }
        match chosen {
            Some((b, l, out)) => {
                let algo = if b == m { "RandGreeDI" } else { "GreedyML" };
                harness::row(
                    &[8, 12, 8, 16, 16, 12, 10],
                    &cells![
                        k,
                        algo,
                        format!("({l},{b})"),
                        out.critical_calls,
                        seq.greedy.calls,
                        format!("{:.2}", 100.0 * out.value / seq.greedy.value),
                        fmt_bytes(out.peak_mem())
                    ],
                );
            }
            None => harness::row(
                &[8, 12, 8, 16, 16, 12, 10],
                &cells![k, "GreedyML", "-", "no tree fits", "-", "-", "-"],
            ),
        }
    }
    println!(
        "\nexpected shape: RandGreeDI fits only the smallest k; larger k needs \
         smaller b (deeper trees); critical-path calls stay below sequential \
         GREEDY; quality within ~6% of GREEDY (§6.2.1)."
    );
}
