//! Ablations for the design choices DESIGN.md calls out:
//!
//!   1. Lazy Greedy vs naive GREEDY (the paper's §5 implementation choice) —
//!      gain-query counts and wall time on coverage workloads.
//!   2. Random tape vs contiguous partition (RandGreeDI's core insight) —
//!      quality on cluster-structured data where contiguity is adversarial.
//!   3. GreedyML argmax (vs own previous solution, Fig. 3) vs RandGreeDI
//!      argmax (vs all children, Alg. 2.2) — quality difference at b = m.
//!   4. CPU oracle vs PJRT kernel backend — batched-gain throughput for
//!      k-medoid (dense: kernel-friendly) and k-cover (sparse: host wins).

#[path = "harness.rs"]
mod harness;

use greedyml::algo::{run_dist, DistConfig, PartitionScheme};
use greedyml::constraint::Cardinality;
use greedyml::data::gen;
use greedyml::greedy::{greedy_lazy, greedy_naive};
use greedyml::objective::{KCover, KMedoid, Oracle};
use greedyml::tree::AccumulationTree;
use std::sync::Arc;

fn main() {
    ablation_lazy();
    ablation_leaf_algorithms();
    ablation_partition();
    ablation_argmax();
    ablation_backend();
}

/// Ablation 1b: alternative leaf algorithms for constrained regimes —
/// Stochastic Greedy (lazier-than-lazy) and Sieve-Streaming (single pass,
/// O(k log k / eps) memory) vs Lazy Greedy, on the same workload.
fn ablation_leaf_algorithms() {
    use greedyml::greedy::{greedy_stochastic, sieve_streaming};
    harness::section("ablation 1b: leaf algorithm alternatives (k-cover, n=20k, k=100)");
    let data = Arc::new(gen::transactions(gen::TransactionParams::kosarak_like(20_000), 5));
    let oracle = KCover::new(data);
    let c = Cardinality::new(100);
    let cands: Vec<u32> = (0..oracle.n() as u32).collect();
    let lazy = greedy_lazy(&oracle, &c, &cands, None);
    let stoch = greedy_stochastic(&oracle, &c, &cands, None, 0.1, 7);
    let sieve = sieve_streaming(&oracle, &c, &cands, None, 0.2);
    harness::row(&[-18, 14, 14, 12], &cells!["algo", "gain queries", "f(S)", "rel f(%)"]);
    for (name, out) in
        [("lazy greedy", &lazy), ("stochastic (e=0.1)", &stoch), ("sieve (e=0.2)", &sieve)]
    {
        harness::row(
            &[-18, 14, 14, 12],
            &cells![name, out.calls, out.value, format!("{:.2}", 100.0 * out.value / lazy.value)],
        );
    }
    println!(
        "stochastic trades <15% quality for O(n ln 1/e) calls; sieve holds only \
         O(k log k / e) elements — the edge regime of §6.2.1"
    );
}

fn ablation_lazy() {
    harness::section("ablation 1: lazy vs naive greedy (k-cover, n=20k, k=100)");
    let data = Arc::new(gen::transactions(gen::TransactionParams::kosarak_like(20_000), 5));
    let oracle = KCover::new(data);
    let c = Cardinality::new(100);
    let cands: Vec<u32> = (0..oracle.n() as u32).collect();
    let t_naive = harness::bench(1, 3, || greedy_naive(&oracle, &c, &cands, None));
    let t_lazy = harness::bench(1, 3, || greedy_lazy(&oracle, &c, &cands, None));
    let naive = greedy_naive(&oracle, &c, &cands, None);
    let lazy = greedy_lazy(&oracle, &c, &cands, None);
    harness::row(&[-8, 14, 12, 14], &cells!["algo", "gain queries", "time (s)", "f(S)"]);
    harness::row(
        &[-8, 14, 12, 14],
        &cells!["naive", naive.calls, format!("{:.4}", t_naive.median), naive.value],
    );
    harness::row(
        &[-8, 14, 12, 14],
        &cells!["lazy", lazy.calls, format!("{:.4}", t_lazy.median), lazy.value],
    );
    println!(
        "lazy uses {:.1}% of naive's queries at identical value",
        100.0 * lazy.calls as f64 / naive.calls as f64
    );
}

fn ablation_partition() {
    harness::section("ablation 2: random tape vs contiguous partition (clustered k-cover)");
    // Blocks of identical transactions: contiguous chunks are redundant.
    let mut sets = Vec::new();
    for block in 0..64u32 {
        for _ in 0..125 {
            let base = block * 6;
            sets.push(vec![base, base + 1, base + 2, base + 3, base + 4, base + 5]);
        }
    }
    let oracle =
        KCover::new(Arc::new(greedyml::data::itemsets::ItemsetCollection::from_sets(&sets)));
    let c = Cardinality::new(16);
    harness::row(&[-12, 14, 12], &cells!["partition", "f(S)", "crit calls"]);
    for (label, scheme) in
        [("random", PartitionScheme::Random), ("contiguous", PartitionScheme::Contiguous)]
    {
        let cfg = DistConfig {
            partition: scheme,
            compare_all_children: true,
            ..DistConfig::greedyml(AccumulationTree::randgreedi(16), 3)
        };
        let out = run_dist(&oracle, &c, &cfg).unwrap();
        harness::row(&[-12, 14, 12], &cells![label, out.value, out.critical_calls]);
    }
    println!("expected: random ≥ contiguous on block-structured data (the RandGreeDI insight)");
}

fn ablation_argmax() {
    harness::section("ablation 3: Fig-3 argmax (own prev) vs Alg-2.2 argmax (all children)");
    let data = Arc::new(gen::transactions(gen::TransactionParams::retail_like(12_000), 7));
    let oracle = KCover::new(data);
    let c = Cardinality::new(64);
    harness::row(&[-14, 8, 14, 14], &cells!["variant", "b", "f(S)", "root calls"]);
    for b in [16u32, 4, 2] {
        for (label, all) in [("own-prev", false), ("all-children", true)] {
            let cfg = DistConfig {
                compare_all_children: all,
                ..DistConfig::greedyml(AccumulationTree::new(16, b), 5)
            };
            let out = run_dist(&oracle, &c, &cfg).unwrap();
            harness::row(
                &[-14, 8, 14, 14],
                &cells![label, b, out.value, out.machines[0].calls],
            );
        }
    }
    println!(
        "expected: values nearly identical (same α/(L+1) guarantee), Fig-3 variant does no \
         extra evaluation work at the root"
    );
}

fn ablation_backend() {
    harness::section("ablation 4: CPU oracle vs PJRT kernel backend (batched gains)");
    let Ok(engine) = greedyml::runtime::Engine::load(&greedyml::runtime::artifact_dir()) else {
        println!("(artifacts not built — skipping)");
        return;
    };
    let engine = Arc::new(engine);

    // Dense: k-medoid gains over a 2048×64 view, 64-candidate batches.
    let (vs, _) = gen::gaussian_mixture(
        gen::GaussianParams { n: 2048, dim: 64, classes: 8, noise: 0.3 },
        3,
    );
    let vs = Arc::new(vs);
    let cpu = KMedoid::new(vs.clone());
    let pjrt = greedyml::runtime::KMedoidPjrt::new(vs, engine.clone()).unwrap();
    let cands: Vec<u32> = (0..512).collect();
    let mut out = Vec::new();
    let st_cpu = cpu.new_state(None);
    let st_pjrt = pjrt.new_state(None);
    let t_cpu = harness::bench(1, 3, || st_cpu.gain_batch(&cands, &mut out));
    let t_pjrt = harness::bench(1, 3, || st_pjrt.gain_batch(&cands, &mut out));
    harness::row(&[-22, 12, 14], &cells!["k-medoid backend", "time (s)", "gains/s"]);
    harness::row(
        &[-22, 12, 14],
        &cells!["cpu", format!("{:.4}", t_cpu.median), format!("{:.0}", 512.0 / t_cpu.median)],
    );
    harness::row(
        &[-22, 12, 14],
        &cells![
            "pjrt (pallas AOT)",
            format!("{:.4}", t_pjrt.median),
            format!("{:.0}", 512.0 / t_pjrt.median)
        ],
    );

    // Sparse: k-cover gains — the host sparse scan vs bitmap kernel.
    let data = Arc::new(gen::transactions(gen::TransactionParams::retail_like(8_000), 9));
    let ccpu = KCover::new(data.clone());
    let cpjrt = greedyml::runtime::KCoverPjrt::new(data, engine).unwrap();
    let cands: Vec<u32> = (0..2048).collect();
    let sc = ccpu.new_state(None);
    let sp = cpjrt.new_state(None);
    let t_c = harness::bench(1, 3, || sc.gain_batch(&cands, &mut out));
    let t_p = harness::bench(1, 3, || sp.gain_batch(&cands, &mut out));
    harness::row(&[-22, 12, 14], &cells!["k-cover backend", "time (s)", "gains/s"]);
    harness::row(
        &[-22, 12, 14],
        &cells![
            "cpu (sparse scan)",
            format!("{:.4}", t_c.median),
            format!("{:.0}", 2048.0 / t_c.median)
        ],
    );
    harness::row(
        &[-22, 12, 14],
        &cells![
            "pjrt (bitmap)",
            format!("{:.4}", t_p.median),
            format!("{:.0}", 2048.0 / t_p.median)
        ],
    );
    println!(
        "expected: PJRT amortizes on dense k-medoid tiles; sparse coverage favours the host \
         scan (packing is Θ(universe) per call)"
    );
}
