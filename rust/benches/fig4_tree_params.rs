//! Fig. 4 — accumulation-tree parameter selection on 32 machines.
//!
//! Left subfigure: execution time for GreedyML across (L, b) as k grows,
//! geometric mean over six datasets (three road-like graphs, three itemset
//! collections — the paper's mix, synthetic per DESIGN.md §2).
//!
//! Right subfigure: number of function calls on the critical path relative
//! to sequential GREEDY at the largest k, per (L, b).
//!
//! Expected shape (paper §6.1): times are flat in b for small k and favour
//! multilevel trees as k grows; RandGreeDI's (L=1, b=32) critical path is
//! the longest because the single accumulation has a k²·m term.

#[path = "harness.rs"]
mod harness;

use greedyml::algo::{run_greedyml, run_sequential, DistConfig};
use greedyml::constraint::Cardinality;
use greedyml::data::gen;
use greedyml::greedy::GreedyKind;
use greedyml::objective::{KCover, KDominatingSet, Oracle};
use greedyml::tree::AccumulationTree;
use std::sync::Arc;

fn datasets() -> Vec<(&'static str, Arc<dyn Oracle>)> {
    vec![
        (
            "road-usa-like",
            Arc::new(KDominatingSet::new(Arc::new(gen::road(
                gen::RoadParams::usa_like(1 << 15),
                1,
            )))),
        ),
        (
            "road-cent-like",
            Arc::new(KDominatingSet::new(Arc::new(gen::road(
                gen::RoadParams::usa_like(1 << 14),
                2,
            )))),
        ),
        (
            "belgium-like",
            Arc::new(KDominatingSet::new(Arc::new(gen::road(
                gen::RoadParams::belgium_like(1 << 14),
                3,
            )))),
        ),
        (
            "webdocs-like",
            Arc::new(KCover::new(Arc::new(gen::transactions(
                gen::TransactionParams {
                    num_sets: 3000,
                    num_items: 12_000,
                    mean_size: 177.2,
                    zipf_s: 1.0,
                },
                4,
            )))),
        ),
        (
            "kosarak-like",
            Arc::new(KCover::new(Arc::new(gen::transactions(
                gen::TransactionParams::kosarak_like(24_000),
                5,
            )))),
        ),
        (
            "retail-like",
            Arc::new(KCover::new(Arc::new(gen::transactions(
                gen::TransactionParams::retail_like(22_000),
                6,
            )))),
        ),
    ]
}

fn main() {
    let m = 32u32;
    let shapes: [(u32, u32); 4] = [(1, 32), (2, 8), (3, 4), (5, 2)]; // (L, b)
    let ks = [125usize, 250, 500, 1000, 2000];
    let sets = datasets();

    harness::section("Fig 4 (left): GreedyML geomean execution time (s) on 32 machines");
    let mut header = cells!["k"];
    header.extend(shapes.iter().map(|(l, b)| format!("L={l},b={b}")));
    harness::row(&[8, 12, 12, 12, 12], &header);

    let mut quality: Vec<Vec<f64>> = vec![Vec::new(); shapes.len()]; // rel to greedy at kmax
    let mut crit_rel: Vec<Vec<f64>> = vec![Vec::new(); shapes.len()];

    for &k in &ks {
        let constraint = Cardinality::new(k);
        let mut col_times: Vec<Vec<f64>> = vec![Vec::new(); shapes.len()];
        for (_, oracle) in &sets {
            // Sequential baseline at the largest k only (expensive).
            let seq = if k == *ks.last().unwrap() {
                Some(run_sequential(oracle.as_ref(), &constraint, GreedyKind::Lazy, None).unwrap())
            } else {
                None
            };
            for (si, &(_, b)) in shapes.iter().enumerate() {
                let tree = AccumulationTree::new(m, b);
                let cfg = DistConfig::greedyml(tree, 9);
                let out = run_greedyml(oracle.as_ref(), &constraint, &cfg).unwrap();
                col_times[si].push(out.total_secs().max(1e-7));
                if let Some(seq) = &seq {
                    quality[si].push(out.value / seq.greedy.value.max(1e-12));
                    crit_rel[si].push(out.critical_calls as f64 / seq.greedy.calls as f64);
                }
            }
        }
        let mut row = cells![k];
        row.extend(col_times.iter().map(|t| format!("{:.4}", harness::geomean(t))));
        harness::row(&[8, 12, 12, 12, 12], &row);
    }

    harness::section(&format!(
        "Fig 4 (right): critical-path calls relative to GREEDY at k={} (geomean over datasets)",
        ks.last().unwrap()
    ));
    harness::row(&[10, 14, 16], &cells!["(L,b)", "rel calls", "rel func value"]);
    for (si, &(l, b)) in shapes.iter().enumerate() {
        harness::row(
            &[10, 14, 16],
            &cells![
                format!("({l},{b})"),
                format!("{:.2}%", 100.0 * harness::geomean(&crit_rel[si])),
                format!("{:.2}%", 100.0 * harness::geomean(&quality[si]))
            ],
        );
    }
    println!(
        "\nexpected: (1,32) ≈ RandGreeDI has the largest relative call count; deeper \
         trees cut it; function values differ from RandGreeDI by <1% (§6.1)."
    );
}
