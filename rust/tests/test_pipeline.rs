//! Full-pipeline integration: config text → experiment → algorithms →
//! reports, across objectives, constraints, partition schemes and
//! failure modes.

use greedyml::coordinator::{render_table, Experiment};
use greedyml::util::config::Config;

fn run_config(text: &str) -> (Vec<greedyml::metrics::RunReport>, Vec<(String, String)>) {
    let cfg = Config::parse(text).unwrap();
    let exp = Experiment::from_config(&cfg, None).unwrap();
    exp.run()
}

#[test]
fn kcover_pipeline_all_algorithms() {
    let (reports, failures) = run_config(
        "name = it\n\
         [dataset]\nkind = kosarak\nn = 2000\nseed = 3\n\
         [problem]\nk = 24\n\
         [run]\nalgos = greedy, greedi:8, randgreedi:8, greedyml:8:2, greedyml:8:4\nseed = 1\n",
    );
    assert!(failures.is_empty(), "{failures:?}");
    assert_eq!(reports.len(), 5);
    let greedy = reports[0].value;
    for r in &reports {
        assert!(r.value > 0.0);
        assert!(r.value <= greedy + 1e-9, "{}: dist beat greedy?", r.algo);
        assert!(r.value >= 0.6 * greedy, "{}: too weak ({} vs {greedy})", r.algo, r.value);
    }
    let table = render_table(&reports, &failures);
    assert!(table.contains("GML(m=8,b=2,L=3)"));
}

#[test]
fn kdominating_pipeline_with_memory_ladder() {
    // A limit that breaks wide trees but not the binary one.
    let base = "name = mem\n\
         [dataset]\nkind = ba\nn = 20000\nattach = 3\nseed = 4\n\
         [problem]\nk = 600\n\
         [run]\nseed = 2\n";
    // Probe unlimited to find the wide-tree peak.
    let cfg = Config::parse(&format!("{base}algos = randgreedi:16\n")).unwrap();
    let mut cfg = cfg;
    cfg.set("run.algos", "randgreedi:16");
    let exp = Experiment::from_config(&cfg, None).unwrap();
    let (reports, failures) = exp.run();
    assert!(failures.is_empty());
    let peak = reports[0].peak_mem;

    let mut cfg2 = Config::parse(base).unwrap();
    cfg2.set("run.algos", "randgreedi:16, greedyml:16:2");
    cfg2.set("run.mem_limit", &format!("{}", peak * 2 / 3));
    let exp2 = Experiment::from_config(&cfg2, None).unwrap();
    let (reports2, failures2) = exp2.run();
    assert_eq!(failures2.len(), 1, "RandGreeDI should OOM: {failures2:?}");
    assert!(failures2[0].0.starts_with("RG"));
    assert_eq!(reports2.len(), 1, "GreedyML(b=2) should succeed");
    assert!(reports2[0].algo.starts_with("GML"));
}

#[test]
fn kmedoid_pipeline_local_view_and_added() {
    let (reports, failures) = run_config(
        "name = med\n\
         [dataset]\nkind = gaussian\nn = 512\ndim = 16\nclasses = 8\nseed = 5\n\
         [objective]\nkind = kmedoid\n\
         [problem]\nk = 12\n\
         [run]\nalgos = randgreedi:8, greedyml:8:2\nlocal_view = true\nadded = 64\nseed = 3\n",
    );
    assert!(failures.is_empty(), "{failures:?}");
    assert_eq!(reports.len(), 2);
    // Local values are not directly comparable to global, but both must be
    // positive and within a sane band of each other.
    let (rg, gml) = (reports[0].value, reports[1].value);
    assert!(rg > 0.0 && gml > 0.0);
    assert!(gml > 0.5 * rg && gml < 2.0 * rg, "rg {rg} vs gml {gml}");
}

#[test]
fn partition_matroid_pipeline() {
    let (reports, failures) = run_config(
        "name = mat\n\
         [dataset]\nkind = retail\nn = 600\nseed = 6\n\
         [problem]\nk = 12\nconstraint = matroid\ngroups = 3\n\
         [run]\nalgos = greedy, greedyml:4:2\nseed = 4\n",
    );
    assert!(failures.is_empty(), "{failures:?}");
    assert_eq!(reports.len(), 2);
    assert!(reports[1].value >= 0.5 * reports[0].value);
}

#[test]
fn reports_are_json_exportable() {
    let (reports, _) = run_config(
        "[dataset]\nkind = retail\nn = 300\n[problem]\nk = 6\n[run]\nalgos = greedyml:4:2\n",
    );
    let path = std::env::temp_dir().join("greedyml_pipeline_report.json");
    greedyml::metrics::write_reports(path.to_str().unwrap(), &reports).unwrap();
    let parsed =
        greedyml::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert_eq!(parsed.as_arr().unwrap().len(), reports.len());
    std::fs::remove_file(&path).ok();
}

#[test]
fn determinism_across_full_pipeline() {
    let text = "[dataset]\nkind = road\nn = 4096\nseed = 9\n\
                [problem]\nk = 64\n\
                [run]\nalgos = greedyml:8:2\nseed = 17\n";
    let (a, _) = run_config(text);
    let (b, _) = run_config(text);
    assert_eq!(a[0].value, b[0].value);
    assert_eq!(a[0].critical_calls, b[0].critical_calls);
    assert_eq!(a[0].total_calls, b[0].total_calls);
    assert_eq!(a[0].peak_mem, b[0].peak_mem);
}
