//! Cross-module property suite (the S7 check harness at integration scope):
//! random instances, structural invariants of the whole distributed stack.

use greedyml::algo::{run_greedyml, DistConfig};
use greedyml::check::{ensure, forall, pair, Gen};
use greedyml::constraint::{Cardinality, Constraint};
use greedyml::data::itemsets::ItemsetCollection;
use greedyml::objective::{KCover, Oracle};
use greedyml::tree::AccumulationTree;
use greedyml::util::rng::Rng;
use std::sync::Arc;

fn random_instance(seed: u64, n: usize, items: usize) -> KCover {
    let mut rng = Rng::new(seed);
    let sets: Vec<Vec<u32>> = (0..n)
        .map(|_| {
            (0..1 + rng.below(6) as usize)
                .map(|_| rng.below(items as u64) as u32)
                .collect()
        })
        .collect();
    KCover::new(Arc::new(ItemsetCollection::from_sets(&sets)))
}

#[test]
fn solution_always_feasible_and_value_consistent() {
    forall(
        "dist solution feasibility",
        40,
        pair(Gen::u64(0..1000), pair(Gen::u64(2..17), Gen::u64(2..6))),
        |&(seed, (m, b))| {
            let oracle = random_instance(seed, 200, 100);
            let k = 8;
            let constraint = Cardinality::new(k);
            let cfg = DistConfig::greedyml(AccumulationTree::new(m as u32, b as u32), seed);
            let out = run_greedyml(&oracle, &constraint, &cfg)
                .map_err(|e| format!("unexpected failure: {e}"))?;
            ensure(constraint.is_feasible(&out.solution), "infeasible solution")?;
            ensure(out.solution.len() <= k, "solution exceeds k")?;
            let fresh = oracle.eval(&out.solution);
            ensure(
                (fresh - out.value).abs() < 1e-9,
                format!("reported {} vs recomputed {fresh}", out.value),
            )?;
            // No duplicate elements.
            let set: std::collections::HashSet<_> = out.solution.iter().collect();
            ensure(set.len() == out.solution.len(), "duplicates in solution")
        },
    );
}

#[test]
fn call_accounting_adds_up() {
    forall(
        "calls: levels sum == machines sum",
        30,
        pair(Gen::u64(0..500), pair(Gen::u64(2..13), Gen::u64(2..5))),
        |&(seed, (m, b))| {
            let oracle = random_instance(seed, 150, 80);
            let cfg = DistConfig::greedyml(AccumulationTree::new(m as u32, b as u32), seed);
            let out = run_greedyml(&oracle, &Cardinality::new(6), &cfg)
                .map_err(|e| format!("{e}"))?;
            let by_levels: u64 = out.levels.iter().map(|l| l.total_calls).sum();
            let by_machines: u64 = out.machines.iter().map(|s| s.calls).sum();
            ensure(
                by_levels == by_machines,
                format!("levels {by_levels} != machines {by_machines}"),
            )?;
            ensure(out.total_calls == by_machines, "total_calls mismatch")?;
            ensure(
                out.critical_calls == out.machines[0].calls,
                "critical path is machine 0",
            )
        },
    );
}

#[test]
fn taller_trees_never_increase_peak_accumulation() {
    forall(
        "peak accumulation monotone in b",
        20,
        Gen::u64(0..300),
        |&seed| {
            let oracle = random_instance(seed, 300, 150);
            let constraint = Cardinality::new(10);
            let mut prev_elems = usize::MAX;
            for b in [16u32, 4, 2] {
                let cfg = DistConfig::greedyml(AccumulationTree::new(16, b), seed);
                let out = run_greedyml(&oracle, &constraint, &cfg).map_err(|e| format!("{e}"))?;
                ensure(
                    out.max_accum_elems <= prev_elems,
                    format!("b={b}: {} > previous {prev_elems}", out.max_accum_elems),
                )?;
                prev_elems = out.max_accum_elems;
            }
            Ok(())
        },
    );
}

#[test]
fn comm_bytes_conserved_and_root_receives_most() {
    forall(
        "conservation of bytes",
        25,
        pair(Gen::u64(0..400), Gen::u64(2..6)),
        |&(seed, b)| {
            let oracle = random_instance(seed, 200, 100);
            let cfg = DistConfig::greedyml(AccumulationTree::new(8, b as u32), seed);
            let out =
                run_greedyml(&oracle, &Cardinality::new(6), &cfg).map_err(|e| format!("{e}"))?;
            let sent: u64 = out.machines.iter().map(|s| s.bytes_sent).sum();
            let recv: u64 = out.machines.iter().map(|s| s.bytes_received).sum();
            ensure(sent == recv, format!("sent {sent} != received {recv}"))?;
            ensure(out.machines[0].bytes_sent == 0, "root must not send")
        },
    );
}

#[test]
fn adding_machines_partitions_all_elements() {
    // Leaf call totals imply every element was scanned exactly once across
    // leaves in the first round of naive greedy — a partition witness at
    // the integration level.
    forall(
        "leaf partition covers ground set",
        20,
        pair(Gen::u64(0..200), Gen::u64(2..33)),
        |&(seed, m)| {
            let oracle = random_instance(seed, 120, 60);
            let cfg = DistConfig {
                kind: greedyml::greedy::GreedyKind::Naive,
                ..DistConfig::greedyml(AccumulationTree::new(m as u32, 2), seed)
            };
            let out =
                run_greedyml(&oracle, &Cardinality::new(1), &cfg).map_err(|e| format!("{e}"))?;
            // With k=1, each leaf does exactly |P_i| gain queries.
            let leaf_calls: u64 = out.levels[0].total_calls;
            ensure(
                leaf_calls == 120,
                format!("leaf scan saw {leaf_calls} elements, want 120"),
            )
        },
    );
}
