//! Cross-module property suite (the S7 check harness at integration scope):
//! random instances, structural invariants of the whole distributed stack.

use greedyml::algo::{run_greedyml, DistConfig};
use greedyml::check::{ensure, forall, pair, Gen};
use greedyml::constraint::{Cardinality, Constraint};
use greedyml::data::itemsets::ItemsetCollection;
use greedyml::objective::{KCover, Oracle};
use greedyml::tree::AccumulationTree;
use greedyml::util::rng::Rng;
use std::sync::Arc;

fn random_instance(seed: u64, n: usize, items: usize) -> KCover {
    let mut rng = Rng::new(seed);
    let sets: Vec<Vec<u32>> = (0..n)
        .map(|_| {
            (0..1 + rng.below(6) as usize)
                .map(|_| rng.below(items as u64) as u32)
                .collect()
        })
        .collect();
    KCover::new(Arc::new(ItemsetCollection::from_sets(&sets)))
}

#[test]
fn solution_always_feasible_and_value_consistent() {
    forall(
        "dist solution feasibility",
        40,
        pair(Gen::u64(0..1000), pair(Gen::u64(2..17), Gen::u64(2..6))),
        |&(seed, (m, b))| {
            let oracle = random_instance(seed, 200, 100);
            let k = 8;
            let constraint = Cardinality::new(k);
            let cfg = DistConfig::greedyml(AccumulationTree::new(m as u32, b as u32), seed);
            let out = run_greedyml(&oracle, &constraint, &cfg)
                .map_err(|e| format!("unexpected failure: {e}"))?;
            ensure(constraint.is_feasible(&out.solution), "infeasible solution")?;
            ensure(out.solution.len() <= k, "solution exceeds k")?;
            let fresh = oracle.eval(&out.solution);
            ensure(
                (fresh - out.value).abs() < 1e-9,
                format!("reported {} vs recomputed {fresh}", out.value),
            )?;
            // No duplicate elements.
            let set: std::collections::HashSet<_> = out.solution.iter().collect();
            ensure(set.len() == out.solution.len(), "duplicates in solution")
        },
    );
}

#[test]
fn call_accounting_adds_up() {
    forall(
        "calls: levels sum == machines sum",
        30,
        pair(Gen::u64(0..500), pair(Gen::u64(2..13), Gen::u64(2..5))),
        |&(seed, (m, b))| {
            let oracle = random_instance(seed, 150, 80);
            let cfg = DistConfig::greedyml(AccumulationTree::new(m as u32, b as u32), seed);
            let out = run_greedyml(&oracle, &Cardinality::new(6), &cfg)
                .map_err(|e| format!("{e}"))?;
            let by_levels: u64 = out.levels.iter().map(|l| l.total_calls).sum();
            let by_machines: u64 = out.machines.iter().map(|s| s.calls).sum();
            ensure(
                by_levels == by_machines,
                format!("levels {by_levels} != machines {by_machines}"),
            )?;
            ensure(out.total_calls == by_machines, "total_calls mismatch")?;
            ensure(
                out.critical_calls == out.machines[0].calls,
                "critical path is machine 0",
            )
        },
    );
}

#[test]
fn taller_trees_never_increase_peak_accumulation() {
    forall(
        "peak accumulation monotone in b",
        20,
        Gen::u64(0..300),
        |&seed| {
            let oracle = random_instance(seed, 300, 150);
            let constraint = Cardinality::new(10);
            let mut prev_elems = usize::MAX;
            for b in [16u32, 4, 2] {
                let cfg = DistConfig::greedyml(AccumulationTree::new(16, b), seed);
                let out = run_greedyml(&oracle, &constraint, &cfg).map_err(|e| format!("{e}"))?;
                ensure(
                    out.max_accum_elems <= prev_elems,
                    format!("b={b}: {} > previous {prev_elems}", out.max_accum_elems),
                )?;
                prev_elems = out.max_accum_elems;
            }
            Ok(())
        },
    );
}

#[test]
fn comm_bytes_conserved_and_root_receives_most() {
    forall(
        "conservation of bytes",
        25,
        pair(Gen::u64(0..400), Gen::u64(2..6)),
        |&(seed, b)| {
            let oracle = random_instance(seed, 200, 100);
            let cfg = DistConfig::greedyml(AccumulationTree::new(8, b as u32), seed);
            let out =
                run_greedyml(&oracle, &Cardinality::new(6), &cfg).map_err(|e| format!("{e}"))?;
            let sent: u64 = out.machines.iter().map(|s| s.bytes_sent).sum();
            let recv: u64 = out.machines.iter().map(|s| s.bytes_received).sum();
            ensure(sent == recv, format!("sent {sent} != received {recv}"))?;
            ensure(out.machines[0].bytes_sent == 0, "root must not send")
        },
    );
}

#[test]
fn adding_machines_partitions_all_elements() {
    // Leaf call totals imply every element was scanned exactly once across
    // leaves in the first round of naive greedy — a partition witness at
    // the integration level.
    forall(
        "leaf partition covers ground set",
        20,
        pair(Gen::u64(0..200), Gen::u64(2..33)),
        |&(seed, m)| {
            let oracle = random_instance(seed, 120, 60);
            let cfg = DistConfig {
                kind: greedyml::greedy::GreedyKind::Naive,
                ..DistConfig::greedyml(AccumulationTree::new(m as u32, 2), seed)
            };
            let out =
                run_greedyml(&oracle, &Cardinality::new(1), &cfg).map_err(|e| format!("{e}"))?;
            // With k=1, each leaf does exactly |P_i| gain queries.
            let leaf_calls: u64 = out.levels[0].total_calls;
            ensure(
                leaf_calls == 120,
                format!("leaf scan saw {leaf_calls} elements, want 120"),
            )
        },
    );
}

#[test]
fn sieve_value_within_half_minus_eps_of_exact_greedy() {
    // Sieve-Streaming's certificate: value >= (1/2 - eps) * OPT, and the
    // exact greedy value is itself <= OPT, so the sieve must clear
    // (1/2 - eps) of whatever greedy achieves on the same instance.
    forall(
        "sieve (1/2 - eps) value bound",
        25,
        pair(Gen::u64(0..800), Gen::u64(3..20)),
        |&(seed, k)| {
            let oracle = random_instance(seed, 250, 120);
            let k = k as usize;
            let constraint = Cardinality::new(k);
            let stream: Vec<u32> = (0..250).collect();
            let eps = greedyml::stream::CORESET_EPSILON;
            let sieve = greedyml::greedy::sieve_streaming(&oracle, &constraint, &stream, None, eps);
            let exact = greedyml::greedy::greedy_lazy(&oracle, &constraint, &stream, None);
            ensure(constraint.is_feasible(&sieve.solution), "sieve infeasible")?;
            ensure(
                sieve.value >= (0.5 - eps) * exact.value - 1e-9,
                format!("sieve {} below (1/2-eps) of greedy {}", sieve.value, exact.value),
            )
        },
    );
}

#[test]
fn sieve_coreset_size_bounded_and_contains_its_solution() {
    // The coreset a node ships is at most O(k*log(k)/eps) elements — the
    // memory bound coreset mode's cost model rests on — and always carries
    // the winning sieve's solution so the certificate survives re-greedy.
    forall(
        "coreset size within O(k log k / eps)",
        25,
        pair(Gen::u64(0..800), Gen::u64(2..25)),
        |&(seed, k)| {
            let oracle = random_instance(seed, 300, 140);
            let k = k as usize;
            let stream: Vec<u32> = (0..300).collect();
            let eps = greedyml::stream::CORESET_EPSILON;
            let cs = greedyml::greedy::sieve_coreset(
                &oracle,
                &Cardinality::new(k),
                &stream,
                None,
                eps,
            );
            let bound = greedyml::stream::coreset_size_bound(k, eps);
            ensure(
                cs.elems.len() <= bound,
                format!("coreset {} exceeds bound {bound} at k={k}", cs.elems.len()),
            )?;
            // Deduped, and a subset of the stream.
            let set: std::collections::HashSet<_> = cs.elems.iter().collect();
            ensure(set.len() == cs.elems.len(), "coreset has duplicates")?;
            ensure(
                cs.elems.iter().all(|e| (*e as usize) < 300),
                "coreset outside ground set",
            )?;
            ensure(
                cs.best.solution.iter().all(|e| set.contains(e)),
                "best sieve solution not contained in its coreset",
            )
        },
    );
}
