//! Runtime end-to-end integration: AOT artifacts → PJRT engine → oracles →
//! distributed GreedyML.  These tests require `make artifacts`; they are
//! skipped (silently pass) when the bundle is missing so `cargo test` works
//! on a fresh checkout.

use greedyml::algo::{run_greedyml, DistConfig};
use greedyml::constraint::Cardinality;
use greedyml::data::gen;
use greedyml::objective::{KMedoid, Oracle};
use greedyml::runtime::{Engine, KCoverPjrt, KMedoidPjrt};
use greedyml::tree::AccumulationTree;
use std::sync::Arc;

fn engine() -> Option<Arc<Engine>> {
    Engine::load("artifacts").ok().map(Arc::new)
}

#[test]
fn engine_loads_every_manifest_entry() {
    let Some(engine) = engine() else { return };
    let m = engine.manifest();
    assert!(m.entries.len() >= 4);
    for e in &m.entries {
        assert!(engine.entry(&e.name).is_ok());
        assert!(!e.inputs.is_empty());
        assert!(!e.outputs.is_empty());
    }
}

#[test]
fn distributed_greedyml_over_pjrt_kmedoid() {
    let Some(engine) = engine() else { return };
    let (vs, _) = gen::gaussian_mixture(
        gen::GaussianParams { n: 768, dim: 64, classes: 6, noise: 0.3 },
        21,
    );
    let vs = Arc::new(vs);
    let cpu = KMedoid::new(vs.clone());
    let pjrt = KMedoidPjrt::new(vs, engine).unwrap();
    let constraint = Cardinality::new(10);
    let cfg =
        DistConfig { local_view: true, ..DistConfig::greedyml(AccumulationTree::new(4, 2), 5) };
    let a = run_greedyml(&cpu, &constraint, &cfg).unwrap();
    let b = run_greedyml(&pjrt, &constraint, &cfg).unwrap();
    // Same algorithm, same tape; only the gain arithmetic differs (f64 vs
    // f32 kernel). Global values must agree tightly.
    let ga = cpu.eval(&a.solution);
    let gb = cpu.eval(&b.solution);
    assert!(
        (ga - gb).abs() < 5e-3 * ga.max(1e-9),
        "cpu-backed {ga} vs pjrt-backed {gb}"
    );
    assert_eq!(a.machines.len(), b.machines.len());
}

#[test]
fn distributed_greedyml_over_pjrt_coverage_exact() {
    let Some(engine) = engine() else { return };
    let data = Arc::new(gen::transactions(gen::TransactionParams::retail_like(1200), 31));
    let cpu = greedyml::objective::KCover::new(data.clone());
    let pjrt = KCoverPjrt::new(data, engine).unwrap();
    let constraint = Cardinality::new(16);
    let cfg = DistConfig::greedyml(AccumulationTree::new(4, 2), 8);
    let a = run_greedyml(&cpu, &constraint, &cfg).unwrap();
    let b = run_greedyml(&pjrt, &constraint, &cfg).unwrap();
    // Integer objective + identical tape ⇒ bit-identical results.
    assert_eq!(a.value, b.value);
    assert_eq!(a.solution, b.solution);
}

#[test]
fn pjrt_engine_is_shareable_across_superstep_threads() {
    // The dist simulator calls the engine from many superstep threads; this
    // exercises the Mutex-serialized Send/Sync wrapper under real fan-out.
    let Some(engine) = engine() else { return };
    let (vs, _) = gen::gaussian_mixture(
        gen::GaussianParams { n: 1024, dim: 64, classes: 4, noise: 0.3 },
        13,
    );
    let pjrt = KMedoidPjrt::new(Arc::new(vs), engine).unwrap();
    let constraint = Cardinality::new(6);
    // 8 leaves → 8 concurrent threads issuing kernel launches.
    let cfg =
        DistConfig { local_view: true, ..DistConfig::greedyml(AccumulationTree::new(8, 2), 2) };
    let out = run_greedyml(&pjrt, &constraint, &cfg).unwrap();
    assert!(out.value > 0.0);
    assert_eq!(out.machines.len(), 8);
}
