//! Theorem 4.4 in practice: on instances small enough to brute-force the
//! optimum, the *expected* value of GreedyML (averaged over random tapes)
//! must clear α/(L+1)·OPT — and empirically sits far above it (§6's
//! observation that quality does not degrade with L).

use greedyml::algo::{run_greedyml, DistConfig};
use greedyml::constraint::Cardinality;
use greedyml::data::itemsets::ItemsetCollection;
use greedyml::objective::{FacilityLocation, KCover, Oracle};
use greedyml::tree::AccumulationTree;
use greedyml::util::rng::Rng;
use std::sync::Arc;

/// Brute-force the optimal k-subset value (n choose k enumeration).
fn brute_force_opt(oracle: &dyn Oracle, k: usize) -> f64 {
    let n = oracle.n();
    assert!(n <= 20, "brute force explodes past n=20");
    let mut best = 0.0f64;
    let mut subset = Vec::with_capacity(k);
    fn recurse(
        oracle: &dyn Oracle,
        start: usize,
        k: usize,
        subset: &mut Vec<u32>,
        best: &mut f64,
    ) {
        if subset.len() == k {
            *best = best.max(oracle.eval(subset));
            return;
        }
        for e in start..oracle.n() {
            subset.push(e as u32);
            recurse(oracle, e + 1, k, subset, best);
            subset.pop();
        }
    }
    recurse(oracle, 0, k, &mut subset, &mut best);
    best
}

fn random_cover_instance(rng: &mut Rng, n: usize, items: usize) -> KCover {
    let sets: Vec<Vec<u32>> = (0..n)
        .map(|_| {
            let size = 1 + rng.below(5) as usize;
            (0..size).map(|_| rng.below(items as u64) as u32).collect()
        })
        .collect();
    KCover::new(Arc::new(ItemsetCollection::from_sets(&sets)))
}

#[test]
fn expected_value_clears_theorem_bound_kcover() {
    let mut rng = Rng::new(101);
    // α for cardinality-constrained greedy is (1 − 1/e).
    let alpha = 1.0 - (-1.0f64).exp();
    for trial in 0..6 {
        let oracle = random_cover_instance(&mut rng, 14, 20);
        let k = 4;
        let opt = brute_force_opt(&oracle, k);
        for (m, b) in [(4u32, 2u32), (8, 2), (9, 3)] {
            let tree = AccumulationTree::new(m, b);
            let levels = tree.levels();
            let bound = alpha / (levels as f64 + 1.0) * opt;
            // Average over random tapes (the theorem is in expectation).
            let mut sum = 0.0;
            let reps = 12;
            for seed in 0..reps {
                let cfg = DistConfig::greedyml(tree, 1000 * trial + seed);
                let out = run_greedyml(&oracle, &Cardinality::new(k), &cfg).unwrap();
                sum += out.value;
            }
            let avg = sum / reps as f64;
            assert!(
                avg >= bound - 1e-9,
                "trial {trial} T({m},{b}): E[f] = {avg:.3} below \
                 α/(L+1)·OPT = {bound:.3} (OPT {opt})"
            );
            // Empirical observation (§6): far better than the worst case.
            assert!(
                avg >= 0.75 * opt,
                "trial {trial} T({m},{b}): E[f] = {avg:.3} surprisingly poor vs OPT {opt}"
            );
        }
    }
}

#[test]
fn expected_value_clears_theorem_bound_facility() {
    let alpha = 1.0 - (-1.0f64).exp();
    for seed in 0..4 {
        let oracle = FacilityLocation::random(10, 12, seed);
        let k = 3;
        let opt = brute_force_opt(&oracle, k);
        let tree = AccumulationTree::new(4, 2);
        let bound = alpha / (tree.levels() as f64 + 1.0) * opt;
        let mut sum = 0.0;
        for tape in 0..10 {
            let cfg = DistConfig::greedyml(tree, 31 * seed + tape);
            sum += run_greedyml(&oracle, &Cardinality::new(k), &cfg).unwrap().value;
        }
        let avg = sum / 10.0;
        assert!(avg >= bound, "seed {seed}: {avg:.4} < bound {bound:.4} (OPT {opt:.4})");
    }
}

#[test]
fn greedyml_l1_matches_randgreedi_guarantee_shape() {
    // At L = 1 the theorem gives α/2 — RandGreeDI's guarantee. Check both
    // algorithms clear it on the same instances.
    let mut rng = Rng::new(7);
    let alpha = 1.0 - (-1.0f64).exp();
    for _ in 0..4 {
        let oracle = random_cover_instance(&mut rng, 12, 16);
        let k = 3;
        let opt = brute_force_opt(&oracle, k);
        let bound = alpha / 2.0 * opt;
        let mut gml_sum = 0.0;
        let mut rg_sum = 0.0;
        for seed in 0..10 {
            let cfg = DistConfig::greedyml(AccumulationTree::randgreedi(4), seed);
            gml_sum += run_greedyml(&oracle, &Cardinality::new(k), &cfg).unwrap().value;
            let opts = greedyml::algo::randgreedi::RandGreediOpts::new(4, seed);
            rg_sum += greedyml::algo::run_randgreedi(&oracle, &Cardinality::new(k), opts)
                .unwrap()
                .value;
        }
        assert!(gml_sum / 10.0 >= bound);
        assert!(rg_sum / 10.0 >= gml_sum / 10.0 - 1e-9, "RG argmax dominates GML's");
    }
}
