//! CLI integration: drive the `greedyml` binary end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_greedyml"))
}

#[test]
fn no_args_prints_usage() {
    let out = bin().output().unwrap();
    assert!(out.status.success());
    assert!(String::from_utf8_lossy(&out.stdout).contains("usage: greedyml"));
}

#[test]
fn unknown_command_fails() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn tree_command_renders_fig2() {
    let out = bin().args(["tree", "--machines", "8", "--branching", "3"]).output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("T(m=8, L=2, b=3)"));
    assert!(text.contains("(1,0) (1,3) (1,6)"));
}

#[test]
fn model_command_prints_table1() {
    let out = bin()
        .args(["model", "--n", "1m", "--k", "10k", "--machines", "32", "--levels", "5"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("RandGreeDI calls/machine"));
    assert!(text.contains("fan-in ceil(m^(1/L))      : 2"));
}

#[test]
fn run_command_with_inline_config_and_overrides() {
    let dir = std::env::temp_dir();
    let cfg = dir.join("greedyml_cli_test.toml");
    std::fs::write(
        &cfg,
        "name = cli\n[dataset]\nkind = retail\nn = 300\n[problem]\nk = 8\n\
         [run]\nalgos = greedy, greedyml:4:2\n",
    )
    .unwrap();
    let json = dir.join("greedyml_cli_test.json");
    let out = bin()
        .args([
            "run",
            "--config",
            cfg.to_str().unwrap(),
            "--set",
            "problem.k=6",
            "--json",
            json.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("k=6"), "override not applied:\n{text}");
    assert!(text.contains("Greedy"));
    assert!(text.contains("GML(m=4,b=2,L=2)"));
    let parsed =
        greedyml::util::json::Json::parse(&std::fs::read_to_string(&json).unwrap()).unwrap();
    assert_eq!(parsed.as_arr().unwrap().len(), 2);
    std::fs::remove_file(&cfg).ok();
    std::fs::remove_file(&json).ok();
}

#[test]
fn run_command_missing_config_errors() {
    let out = bin().args(["run", "--config", "/nonexistent.toml"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("cannot read"));
}

#[test]
fn datasets_command_prints_table2() {
    let out = bin().arg("datasets").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for name in ["road-like", "friendster-like", "kosarak-like", "tiny-imagenet-like"] {
        assert!(text.contains(name), "missing {name} in:\n{text}");
    }
}

#[test]
fn artifacts_command_if_built() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return;
    }
    let out = bin().arg("artifacts").output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("coverage_gains"));
}

#[test]
fn run_command_exports_chrome_trace() {
    let dir = std::env::temp_dir();
    let cfg = dir.join("greedyml_cli_trace.toml");
    std::fs::write(
        &cfg,
        "[dataset]\nkind = retail\nn = 200\n[problem]\nk = 6\n[run]\nalgos = greedyml:4:2\n",
    )
    .unwrap();
    let trace = dir.join("greedyml_cli_trace.json");
    let out = bin()
        .args(["run", "--config", cfg.to_str().unwrap(), "--trace", trace.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let parsed =
        greedyml::util::json::Json::parse(&std::fs::read_to_string(&trace).unwrap()).unwrap();
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    // 4 leaves + 2 level-1 nodes + 1 root = 7 compute spans + 3 recv
    // spans, plus one memory-watermark counter per step.
    assert!(events.len() >= 8, "{} events", events.len());
    let spans =
        events.iter().filter(|e| e.get("ph").unwrap().as_str() == Some("X")).count();
    let counters =
        events.iter().filter(|e| e.get("ph").unwrap().as_str() == Some("C")).count();
    assert_eq!(spans + counters, events.len(), "only spans and counters");
    assert!(spans >= 8, "{spans} spans");
    assert_eq!(counters, 7, "one watermark per (machine, level) step");
    std::fs::remove_file(&cfg).ok();
    std::fs::remove_file(&trace).ok();
}

#[test]
fn run_command_under_process_backend() {
    // End-to-end worker protocol: the launched binary forks itself as
    // `greedyml worker` once per machine.  Same config on both backends
    // must report the same objective value in the JSON output.
    let dir = std::env::temp_dir();
    let cfg = dir.join("greedyml_cli_proc.toml");
    std::fs::write(
        &cfg,
        "name = proc\n[dataset]\nkind = retail\nn = 300\n[problem]\nk = 8\n\
         [run]\nalgos = greedyml:4:2\nseed = 5\n",
    )
    .unwrap();
    let run = |backend: &str, json: &std::path::Path| {
        let out = bin()
            .args([
                "run",
                "--config",
                cfg.to_str().unwrap(),
                "--backend",
                backend,
                "--json",
                json.to_str().unwrap(),
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{backend}: {}", String::from_utf8_lossy(&out.stderr));
        let parsed =
            greedyml::util::json::Json::parse(&std::fs::read_to_string(json).unwrap()).unwrap();
        let rows = parsed.as_arr().unwrap();
        assert_eq!(rows.len(), 1);
        rows[0].get("value").unwrap().as_f64().unwrap()
    };
    let tj = dir.join("greedyml_cli_proc_thread.json");
    let pj = dir.join("greedyml_cli_proc_process.json");
    let tv = run("thread", &tj);
    let pv = run("process", &pj);
    assert_eq!(tv.to_bits(), pv.to_bits(), "thread {tv} vs process {pv}");
    std::fs::remove_file(&cfg).ok();
    std::fs::remove_file(&tj).ok();
    std::fs::remove_file(&pj).ok();
}

#[test]
fn run_command_with_partition_shipping_matches_thread() {
    // The `--ship partition` flag end to end: workers receive O(n/m)
    // shards instead of rebuild recipes, and the reported objective is
    // bit-identical to the thread backend's.
    let dir = std::env::temp_dir();
    let cfg = dir.join("greedyml_cli_ship.toml");
    std::fs::write(
        &cfg,
        "name = ship\n[dataset]\nkind = retail\nn = 300\n[problem]\nk = 8\n\
         [run]\nalgos = greedyml:4:2\nseed = 5\n",
    )
    .unwrap();
    let run = |extra: &[&str], json: &std::path::Path| {
        let mut args = vec!["run", "--config", cfg.to_str().unwrap(), "--json"];
        args.push(json.to_str().unwrap());
        args.extend_from_slice(extra);
        let out = bin().args(&args).output().unwrap();
        assert!(out.status.success(), "{extra:?}: {}", String::from_utf8_lossy(&out.stderr));
        let parsed =
            greedyml::util::json::Json::parse(&std::fs::read_to_string(json).unwrap()).unwrap();
        parsed.as_arr().unwrap()[0].get("value").unwrap().as_f64().unwrap()
    };
    let tj = dir.join("greedyml_cli_ship_thread.json");
    let pj = dir.join("greedyml_cli_ship_part.json");
    let tv = run(&["--backend", "thread"], &tj);
    let pv = run(&["--backend", "process", "--ship", "partition"], &pj);
    assert_eq!(tv.to_bits(), pv.to_bits(), "thread {tv} vs partition-shipped {pv}");
    std::fs::remove_file(&cfg).ok();
    std::fs::remove_file(&tj).ok();
    std::fs::remove_file(&pj).ok();
}

#[test]
fn submit_command_gateway_json_matches_local_json() {
    use std::io::{BufRead, BufReader};
    use std::process::Stdio;
    // One [jobs] batch through a live gateway daemon and through the
    // in-process queue: the per-job `--json` records must agree, id for
    // id and bit for bit.  Status words are not compared — the gateway
    // schedules concurrently, so its warm/cold split may legitimately
    // differ from the sequential local run's.
    let dir = std::env::temp_dir();
    let cfg = dir.join("greedyml_cli_gateway.toml");
    std::fs::write(
        &cfg,
        "[dataset]\nkind = retail\nn = 300\nseed = 2\n\
         [jobs]\nks = 4, 8\nseeds = 5, 6\nmachines = 4\nbackend = thread\n",
    )
    .unwrap();
    let mut daemon = bin()
        .args(["gateway", "--bind", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .spawn()
        .unwrap();
    let mut banner = String::new();
    BufReader::new(daemon.stdout.as_mut().unwrap()).read_line(&mut banner).unwrap();
    let addr = banner.trim().rsplit(' ').next().unwrap_or_default().to_string();
    assert!(banner.contains("listening on") && addr.contains(':'), "{banner:?}");

    let submit = |extra: &[&str]| {
        let mut args = vec!["submit", "--config", cfg.to_str().unwrap(), "--json"];
        args.extend_from_slice(extra);
        let out = bin().args(&args).output().unwrap();
        assert!(out.status.success(), "{extra:?}: {}", String::from_utf8_lossy(&out.stderr));
        greedyml::util::json::Json::parse(&String::from_utf8_lossy(&out.stdout)).unwrap()
    };
    let local = submit(&[]);
    let remote = submit(&["--gateway", &addr]);
    let _ = daemon.kill();
    let _ = daemon.wait();

    let rows = |doc: &greedyml::util::json::Json| {
        doc.get("jobs")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|r| {
                (
                    r.get("id").unwrap().as_u64().unwrap(),
                    // Both paths carry the dataset epoch (0 for this
                    // static batch) — schema-identical local vs gateway.
                    r.get("epoch").unwrap().as_u64().unwrap(),
                    r.get("k").unwrap().as_u64().unwrap(),
                    r.get("seed").unwrap().as_u64().unwrap(),
                    r.get("value").unwrap().as_f64().unwrap().to_bits(),
                )
            })
            .collect::<Vec<_>>()
    };
    assert_eq!(rows(&local), rows(&remote), "gateway and local runs must agree per job");
    // The queue blocks carry the same six counters on both paths; the
    // daemon was fresh, so its daemon-wide tallies equal this batch's.
    for doc in [&local, &remote] {
        let q = doc.get("queue").unwrap();
        assert_eq!(q.get("submitted").unwrap().as_u64().unwrap(), 4);
        assert_eq!(q.get("cached").unwrap().as_u64().unwrap(), 0);
        assert_eq!(q.get("rejected").unwrap().as_u64().unwrap(), 0);
        assert_eq!(q.get("failed").unwrap().as_u64().unwrap(), 0);
        assert!(q.get("warm_jobs").is_some() && q.get("init_bytes_total").is_some());
    }
    std::fs::remove_file(&cfg).ok();
}

#[test]
fn sweep_command_emits_figure_csvs() {
    let dir = std::env::temp_dir();
    let cfg = dir.join("greedyml_cli_sweep_csv.toml");
    std::fs::write(
        &cfg,
        "[dataset]\nkind = retail\nn = 300\nseed = 2\n\
         [sweep]\nks = 4, 8\nalgos = randgreedi:4, greedyml:4:2\nreps = 1\n",
    )
    .unwrap();
    let csv_dir = dir.join("greedyml_cli_sweep_csv_out");
    let out = bin()
        .args(["sweep", "--config", cfg.to_str().unwrap(), "--csv", csv_dir.to_str().unwrap()])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    for name in ["fig4_tree_params.csv", "fig5_memory_vary_k.csv", "fig6_strong_scaling.csv"] {
        let text = std::fs::read_to_string(csv_dir.join(name)).unwrap();
        assert_eq!(text.lines().count(), 5, "{name}: header + 2 ks × 2 algos:\n{text}");
        assert!(text.starts_with("algo,dataset,k,"), "{name}:\n{text}");
    }
    std::fs::remove_file(&cfg).ok();
    std::fs::remove_dir_all(&csv_dir).ok();
}
