//! Deterministic fault injection end to end: `GREEDYML_FAULT_PLAN` kills
//! workers at scripted protocol points, and the three `--on-fault`
//! policies must do exactly what `docs/failure-model.md` promises —
//! `retry` re-dispatches the dead machine and stays bit-identical to the
//! fault-free thread backend, `degrade` completes with a feasible
//! solution and full accounting, `fail` surfaces the first fault as a
//! retryable transport error.
//!
//! Process-backend plans travel through this test process's own
//! environment (spawned workers inherit it), so those tests serialize on
//! a lock and scrub the variable when done.  Tcp-backend plans are set on
//! individual `greedyml serve` daemons instead — the coordinator's
//! environment stays clean and daemons can be faulted selectively.

use greedyml::algo::{run_dist, DistConfig, DistOutcome};
use greedyml::coordinator::{build_problem, experiment::build_constraint, problem_spec};
use greedyml::dist::{BackendSpec, DistError, FaultSpec};
use greedyml::tree::AccumulationTree;
use greedyml::util::config::Config;
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::{Mutex, MutexGuard};

/// The real `greedyml` binary — process-backend workers and tcp `serve`
/// daemons.
fn worker_bin() -> String {
    env!("CARGO_BIN_EXE_greedyml").to_string()
}

/// Serializes the tests whose fault plans live in this process's
/// environment (the process backend spawns workers that inherit it).
static FAULT_PLAN_ENV: Mutex<()> = Mutex::new(());

/// Sets `GREEDYML_FAULT_PLAN` for the guard's lifetime; process-backend
/// workers spawned while it lives inherit the plan.
struct PlanEnv<'a>(#[allow(dead_code)] MutexGuard<'a, ()>);

impl PlanEnv<'_> {
    fn set(plan: &str) -> Self {
        let guard = FAULT_PLAN_ENV.lock().unwrap_or_else(|e| e.into_inner());
        std::env::set_var("GREEDYML_FAULT_PLAN", plan);
        PlanEnv(guard)
    }
}

impl Drop for PlanEnv<'_> {
    fn drop(&mut self) {
        std::env::remove_var("GREEDYML_FAULT_PLAN");
    }
}

/// One spawned `greedyml serve` daemon on an ephemeral localhost port
/// with its own extra environment, killed on drop.  The daemon never
/// inherits this process's `GREEDYML_FAULT_PLAN` — a concurrently
/// running process-backend test must not fault someone else's daemon.
struct ServeDaemon {
    child: Child,
    addr: String,
}

impl ServeDaemon {
    fn spawn(env: &[(&str, &str)]) -> Self {
        let mut cmd = Command::new(worker_bin());
        cmd.args(["serve", "--bind", "127.0.0.1:0"])
            .env_remove("GREEDYML_FAULT_PLAN")
            .stdout(Stdio::piped());
        for (k, v) in env {
            cmd.env(k, v);
        }
        let mut child = cmd.spawn().expect("spawn greedyml serve");
        let mut line = String::new();
        BufReader::new(child.stdout.as_mut().expect("piped stdout"))
            .read_line(&mut line)
            .expect("read listen line");
        let addr = line
            .trim()
            .rsplit(' ')
            .next()
            .unwrap_or_default()
            .to_string();
        assert!(
            line.contains("listening on") && addr.contains(':'),
            "unexpected serve banner: {line:?}"
        );
        ServeDaemon { child, addr }
    }
}

impl Drop for ServeDaemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

const SPEC: &str = "[dataset]\nkind = retail\nn = 500\nseed = 2\n[problem]\nk = 10\n";

/// Build the shared workload and run it under `cfg`.
fn run(cfg: &DistConfig) -> Result<DistOutcome, DistError> {
    let parsed = Config::parse(SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    run_dist(problem.oracle.as_ref(), constraint.as_ref(), cfg)
}

fn thread_cfg() -> DistConfig {
    DistConfig {
        backend: BackendSpec::Thread,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), 42)
    }
}

fn process_cfg(on_fault: FaultSpec) -> DistConfig {
    let parsed = Config::parse(SPEC).unwrap();
    DistConfig {
        backend: BackendSpec::Process,
        problem: Some(problem_spec(&parsed)),
        worker_bin: Some(worker_bin()),
        on_fault,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), 42)
    }
}

fn tcp_cfg(on_fault: FaultSpec, daemons: &[ServeDaemon]) -> DistConfig {
    let parsed = Config::parse(SPEC).unwrap();
    DistConfig {
        backend: BackendSpec::Tcp,
        problem: Some(problem_spec(&parsed)),
        hosts: Some(daemons.iter().map(|d| d.addr.clone()).collect()),
        on_fault,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), 42)
    }
}

// ---- process backend ----------------------------------------------------

#[test]
fn process_retry_replays_a_killed_worker_bit_identically() {
    // Machine 1's worker dies the moment it receives its Leaf command;
    // the supervisor respawns it (scrubbed of the plan), replays the
    // session log, and the run must end bit-identical to the fault-free
    // thread backend — retries cost wall time, never quality.
    let plan = PlanEnv::set("kill:m1@leaf");
    let retried = run(&process_cfg(FaultSpec::Retry)).expect("supervised process run");
    drop(plan);
    let thread = run(&thread_cfg()).expect("thread run");
    assert_eq!(retried.solution, thread.solution, "retry must not change the answer");
    assert_eq!(retried.value.to_bits(), thread.value.to_bits());
    assert_eq!(retried.critical_calls, thread.critical_calls);
    assert_eq!(retried.total_calls, thread.total_calls);
    assert!(retried.faults.faults_seen >= 1, "{:?}", retried.faults);
    assert!(retried.faults.retries >= 1, "{:?}", retried.faults);
    assert!(retried.faults.machines_dropped.is_empty(), "retry drops nobody");
}

#[test]
fn process_degrade_completes_with_accounting() {
    // Machine 3 (a pure leaf) dies; degrade drops its contribution and
    // finishes with a feasible solution plus honest accounting for what
    // the answer never saw.
    let plan = PlanEnv::set("kill:m3@leaf");
    let degraded = run(&process_cfg(FaultSpec::Degrade)).expect("degraded run completes");
    drop(plan);
    assert!(!degraded.solution.is_empty());
    assert!(degraded.solution.len() <= 10, "k = 10 must still bind");
    assert!(degraded.value > 0.0);
    assert_eq!(degraded.faults.machines_dropped, vec![3]);
    assert!(degraded.faults.elements_lost > 0, "{:?}", degraded.faults);
    assert!(degraded.faults.faults_seen >= 1, "{:?}", degraded.faults);
}

#[test]
fn process_fail_policy_surfaces_the_injected_fault() {
    // The pre-supervision behavior, verbatim: first transport fault
    // aborts the run with a retryable error that nothing retries.
    let plan = PlanEnv::set("kill:m1@leaf");
    let err = run(&process_cfg(FaultSpec::Fail)).expect_err("fail must abort");
    drop(plan);
    assert!(err.is_retryable(), "worker death is a transport fault: {err}");
    assert!(matches!(err, DistError::Transport { .. }), "{err}");
}

#[test]
fn injected_delay_changes_timing_but_never_bits() {
    // A delay is jitter, not a fault: no report entries, and the answer
    // is bit-identical to the undelayed thread run.
    let plan = PlanEnv::set("delay:m2@job:50ms");
    let delayed = run(&process_cfg(FaultSpec::Retry)).expect("delayed run");
    drop(plan);
    let thread = run(&thread_cfg()).expect("thread run");
    assert_eq!(delayed.solution, thread.solution);
    assert_eq!(delayed.value.to_bits(), thread.value.to_bits());
    assert!(delayed.faults.is_empty(), "a delay is not a fault: {:?}", delayed.faults);
}

// ---- tcp backend --------------------------------------------------------

#[test]
fn tcp_retry_migrates_a_killed_session_to_the_next_host_bit_identically() {
    // Machines 0 and 2 land on the healthy daemon, 1 and 3 on the doomed
    // one (round-robin placement).  Machine 1's session is killed at its
    // Leaf command; the revival ring dials the *next* host — the healthy
    // daemon, which carries no plan — replays the session log there, and
    // the run ends bit-identical to the thread backend.
    let healthy = ServeDaemon::spawn(&[]);
    let doomed = ServeDaemon::spawn(&[("GREEDYML_FAULT_PLAN", "kill:m1@leaf")]);
    let daemons = [healthy, doomed];
    let retried = run(&tcp_cfg(FaultSpec::Retry, &daemons)).expect("supervised tcp run");
    let thread = run(&thread_cfg()).expect("thread run");
    assert_eq!(retried.solution, thread.solution, "migration must not change the answer");
    assert_eq!(retried.value.to_bits(), thread.value.to_bits());
    assert_eq!(retried.critical_calls, thread.critical_calls);
    assert!(retried.faults.faults_seen >= 1, "{:?}", retried.faults);
    assert!(retried.faults.retries >= 1, "{:?}", retried.faults);
}

#[test]
fn tcp_degrade_reports_the_lost_machine_and_finishes() {
    // All four machines on one daemon whose plan kills machine 3's
    // session at its Leaf command; the other sessions are untouched
    // (plans filter by machine) and the run completes degraded.
    let daemons = [ServeDaemon::spawn(&[("GREEDYML_FAULT_PLAN", "kill:m3@leaf")])];
    let degraded = run(&tcp_cfg(FaultSpec::Degrade, &daemons)).expect("degraded tcp run");
    assert!(!degraded.solution.is_empty());
    assert!(degraded.solution.len() <= 10, "k = 10 must still bind");
    assert!(degraded.value > 0.0);
    assert_eq!(degraded.faults.machines_dropped, vec![3]);
    assert!(degraded.faults.elements_lost > 0, "{:?}", degraded.faults);
}

#[test]
fn tcp_fail_policy_preserves_fail_fast() {
    let daemons = [ServeDaemon::spawn(&[("GREEDYML_FAULT_PLAN", "kill:m1@leaf")])];
    let err = run(&tcp_cfg(FaultSpec::Fail, &daemons)).expect_err("fail must abort");
    assert!(matches!(err, DistError::Transport { .. }), "{err}");
    assert!(err.is_retryable(), "so `--on-fault retry` could have handled it: {err}");
}
