//! The binary wire codec battery (wire protocol v5).
//!
//! Locks down the `--wire binary` encoding from the outside: seeded
//! arbitrary payloads across every oracle family round-trip bitwise
//! through `encode_binary`/`decode_binary` and through the framed
//! `write_cmd`/`read_cmd`/`read_session_init` paths, and a mutation fuzz
//! battery (truncations at every section boundary, header byte flips,
//! oversized declared lengths) proves hostile bytes surface as *typed*
//! errors — `Err(String)` at the payload layer, `DistError` at the frame
//! layer — and never as a panic or an unbounded allocation.

use greedyml::dist::wire::{read_cmd, read_reply, read_session_init, write_cmd, write_reply};
use greedyml::dist::WireMode;
use greedyml::objective::{PartitionData, PartitionDecoder, PartitionPayload};
use greedyml::util::rng::Rng;

// ---- seeded payload generator -----------------------------------------

/// Draw `len` global element ids: distinct, shard-ordered arbitrarily,
/// bounded by `n_global`.
fn gen_elems(rng: &mut Rng, n_global: usize, len: usize) -> Vec<u32> {
    let mut elems: Vec<u32> =
        rng.sample_distinct(n_global, len).into_iter().map(|e| e as u32).collect();
    rng.shuffle(&mut elems);
    elems
}

/// Coverage-family shard with ragged CSR rows (including empty rows and,
/// sometimes, a trailing run of empty rows — the case that exercises the
/// decoder's zero-length-section handling).
fn gen_cover(
    rng: &mut Rng,
    weighted: bool,
    self_cover: bool,
    dominating: bool,
) -> PartitionPayload {
    let n_global = 4 + rng.below(2000) as usize;
    let len = rng.below(n_global.min(40) as u64 + 1) as usize;
    let universe = if dominating { n_global } else { 1 + rng.below(500) as usize };
    let elems = gen_elems(rng, n_global, len);
    let mut offsets = vec![0u64];
    let mut items = Vec::new();
    for i in 0..len {
        // Ragged: empty rows are common, and the last rows are often empty.
        let row = if rng.bool(0.3) || (i + 2 >= len && rng.bool(0.5)) {
            0
        } else {
            rng.below(12) as usize
        };
        let mut row_items: Vec<u32> = rng
            .sample_distinct(universe, row.min(universe))
            .into_iter()
            .map(|x| x as u32)
            .collect();
        row_items.sort_unstable();
        offsets.push(offsets.last().unwrap() + row_items.len() as u64);
        items.extend(row_items);
    }
    let weights = weighted.then(|| {
        let mut present: Vec<u32> = items.clone();
        present.sort_unstable();
        present.dedup();
        present.into_iter().map(|i| (i, rng.f64() * 10.0 - 5.0)).collect()
    });
    PartitionPayload {
        n_global,
        elems,
        data: PartitionData::Cover { universe, offsets, items, weights, self_cover, dominating },
    }
}

fn gen_vectors(rng: &mut Rng) -> PartitionPayload {
    let n_global = 2 + rng.below(3000) as usize;
    let len = rng.below(n_global.min(30) as u64 + 1) as usize;
    let dim = 1 + rng.below(16) as usize;
    let flat = (0..len * dim).map(|_| rng.f32() * 8.0 - 4.0).collect();
    PartitionPayload {
        n_global,
        elems: gen_elems(rng, n_global, len),
        data: PartitionData::Vectors { dim, flat },
    }
}

fn gen_facility(rng: &mut Rng) -> PartitionPayload {
    let n_global = 2 + rng.below(1000) as usize;
    let len = rng.below(n_global.min(20) as u64 + 1) as usize;
    let clients = 1 + rng.below(12) as usize;
    let columns = (0..len * clients).map(|_| rng.f64() * 3.0).collect();
    PartitionPayload {
        n_global,
        elems: gen_elems(rng, n_global, len),
        data: PartitionData::Facility { clients, columns },
    }
}

fn gen_modular(rng: &mut Rng) -> PartitionPayload {
    let n_global = 1 + rng.below(100_000) as usize;
    let len = rng.below(n_global.min(25) as u64 + 1) as usize;
    let weights = (0..len).map(|_| rng.f64() * 100.0 - 50.0).collect();
    PartitionPayload {
        n_global,
        elems: gen_elems(rng, n_global, len),
        data: PartitionData::Modular { weights },
    }
}

/// One arbitrary payload; `pick` cycles through every family and flag
/// combination so a seeded loop covers them all.
fn gen_payload(rng: &mut Rng, pick: u64) -> PartitionPayload {
    match pick % 8 {
        0 => gen_cover(rng, false, false, false), // k-cover
        1 => gen_cover(rng, true, false, false),  // weighted cover
        2 => gen_cover(rng, false, true, true),   // k-dominating-set
        3 => gen_cover(rng, false, false, true),  // open-neighbourhood dominating
        4 => gen_cover(rng, true, true, false),   // weighted + self-cover
        5 => gen_vectors(rng),                    // k-medoid
        6 => gen_facility(rng),
        _ => gen_modular(rng),
    }
}

/// The hand-picked edge cases every run must cover regardless of seed.
fn edge_payloads() -> Vec<PartitionPayload> {
    vec![
        // Empty shard (a machine the tape assigned nothing to).
        PartitionPayload {
            n_global: 100,
            elems: vec![],
            data: PartitionData::Modular { weights: vec![] },
        },
        // Empty cover shard: every section has length zero.
        PartitionPayload {
            n_global: 50,
            elems: vec![],
            data: PartitionData::Cover {
                universe: 9,
                offsets: vec![0],
                items: vec![],
                weights: None,
                self_cover: false,
                dominating: false,
            },
        },
        // Single element, empty row: the items section is the zero-length
        // *last* section, completed with no trailing feed bytes.
        PartitionPayload {
            n_global: 10,
            elems: vec![7],
            data: PartitionData::Cover {
                universe: 4,
                offsets: vec![0, 0],
                items: vec![],
                weights: None,
                self_cover: true,
                dominating: false,
            },
        },
        // Single element, single weight.
        PartitionPayload {
            n_global: 2,
            elems: vec![1],
            data: PartitionData::Modular { weights: vec![-0.0] },
        },
        // Ragged CSR: a fat row between empties, items needing width 4.
        PartitionPayload {
            n_global: 1 << 20,
            elems: vec![0, 1 << 19, 3],
            data: PartitionData::Cover {
                universe: 1 << 18,
                offsets: vec![0, 0, 300, 300],
                items: (0..300).map(|i| i * 800).collect(),
                weights: None,
                self_cover: false,
                dominating: false,
            },
        },
        // Weighted cover with non-finite-adjacent bit patterns.
        PartitionPayload {
            n_global: 8,
            elems: vec![2, 5],
            data: PartitionData::Cover {
                universe: 3,
                offsets: vec![0, 1, 3],
                items: vec![1, 0, 2],
                weights: Some(vec![(0, f64::MIN_POSITIVE), (1, 1e300), (2, -0.0)]),
                self_cover: false,
                dominating: true,
            },
        },
        // Tiny vector shard with subnormal-adjacent f32 bit patterns.
        PartitionPayload {
            n_global: 5,
            elems: vec![0, 4],
            data: PartitionData::Vectors { dim: 2, flat: vec![0.5, -0.5, f32::MIN_POSITIVE, 3.0] },
        },
    ]
}

/// Every payload the battery runs: seeded arbitraries plus the edges.
fn battery(seed: u64, arbitrary: usize) -> Vec<PartitionPayload> {
    let mut rng = Rng::new(seed);
    let mut all = edge_payloads();
    for pick in 0..arbitrary as u64 {
        all.push(gen_payload(&mut rng, pick));
    }
    all
}

fn encode(p: &PartitionPayload) -> Vec<u8> {
    let mut out = Vec::new();
    p.encode_binary(&mut out);
    out
}

/// Byte offsets of the section boundaries inside an encoded payload
/// (derived from the self-describing header, not the encoder internals).
fn section_boundaries(bytes: &[u8]) -> Vec<usize> {
    let n_sections = bytes[2] as usize;
    let mut at = 20 + 9 * n_sections;
    let mut cuts = vec![at];
    for i in 0..n_sections {
        let desc = 20 + 9 * i;
        let len = u64::from_le_bytes(bytes[desc..desc + 8].try_into().unwrap()) as usize;
        at += len;
        cuts.push(at);
    }
    cuts
}

// ---- round-trip ------------------------------------------------------

#[test]
fn seeded_payloads_roundtrip_bitwise() {
    for payload in battery(0xB1AB, 64) {
        let bytes = encode(&payload);
        assert_eq!(bytes.len(), payload.binary_len(), "binary_len must predict the encoding");
        let back = PartitionPayload::decode_binary(&bytes).unwrap_or_else(|e| {
            panic!("decode failed for {payload:?}: {e}");
        });
        // PartitionData's PartialEq compares floats with ==, which is
        // bitwise for every value the generator emits except NaN (never
        // generated); the f64 sections travel as to_bits so equality here
        // is bit-exactness.
        assert_eq!(back, payload);
        assert_eq!(encode(&back), bytes, "re-encoding must reproduce the exact bytes");
    }
}

#[test]
fn streaming_decode_agrees_with_one_shot_for_every_chunking() {
    // The worker's streaming ingest path must produce the same payload
    // regardless of how the transport slices the bytes.
    let mut rng = Rng::new(77);
    for payload in battery(0xFEED, 24) {
        let bytes = encode(&payload);
        for chunk_size in [1, 2, 7, 64, bytes.len().max(1)] {
            let mut dec = PartitionDecoder::new(bytes.len());
            for chunk in bytes.chunks(chunk_size.min(bytes.len()).max(1)) {
                dec.feed(chunk).unwrap();
            }
            assert!(dec.is_complete());
            assert_eq!(dec.finish().unwrap(), payload);
        }
        // And one random ragged chunking.
        let mut dec = PartitionDecoder::new(bytes.len());
        let mut at = 0;
        while at < bytes.len() {
            let take = 1 + rng.below((bytes.len() - at) as u64) as usize;
            dec.feed(&bytes[at..at + take]).unwrap();
            at += take;
        }
        assert_eq!(dec.finish().unwrap(), payload);
    }
}

#[test]
fn framed_init_part_roundtrips_through_both_read_paths() {
    use greedyml::dist::wire::ToWorker;
    for (i, payload) in battery(0xCAFE, 16).into_iter().enumerate() {
        let cmd = ToWorker::InitPart { session: i as u64, machine: 3, threads: 2, payload };
        let mut buf = Vec::new();
        write_cmd(&mut buf, &cmd, WireMode::Binary).unwrap();
        let (via_read_cmd, mode) = read_cmd(&mut buf.as_slice()).unwrap().expect("frame");
        assert_eq!(via_read_cmd, cmd);
        assert_eq!(mode, WireMode::Binary);
        let (via_stream, mode) = read_session_init(&mut buf.as_slice()).unwrap().expect("frame");
        assert_eq!(via_stream, cmd, "streaming and buffered reads must agree");
        assert_eq!(mode, WireMode::Binary);
    }
}

#[test]
fn framed_sol_roundtrips_with_extracted_shard() {
    use greedyml::dist::node::ChildMsg;
    use greedyml::dist::wire::FromWorker;
    for (i, payload) in battery(0xD00D, 12).into_iter().enumerate() {
        let sol = payload.elems.clone();
        // Alternate coreset-mode messages through the same battery.
        let coreset = (i % 2 == 0).then(|| sol.clone());
        let msg = FromWorker::Sol(ChildMsg {
            from: i as u32,
            sol,
            value: 0.1 + i as f64 / 3.0,
            bytes: 17 * i as u64,
            data: Some(payload),
            coreset,
        });
        let mut buf = Vec::new();
        write_reply(&mut buf, &msg, WireMode::Binary).unwrap();
        assert_eq!(read_reply(&mut buf.as_slice()).unwrap().unwrap(), msg);
    }
}

// ---- mutation fuzz ---------------------------------------------------

#[test]
fn truncation_at_every_section_boundary_is_a_typed_error() {
    for payload in battery(0x7E57, 12) {
        let bytes = encode(&payload);
        let mut cuts = section_boundaries(&bytes);
        // Also cut inside the fixed header and inside the section table.
        cuts.extend([1, 3, 12, 21]);
        for cut in cuts {
            if cut >= bytes.len() {
                continue;
            }
            // One-shot decode of a short buffer: the header's declared
            // total no longer matches, or a section never completes.
            let err = PartitionPayload::decode_binary(&bytes[..cut])
                .expect_err("truncated payload must not decode");
            assert!(!err.is_empty());
            // Streaming decode that is told the true length but starved
            // of the tail: finish() reports the truncation.
            let mut dec = PartitionDecoder::new(bytes.len());
            dec.feed(&bytes[..cut]).unwrap();
            let err = dec.finish().expect_err("starved decoder must not finish");
            assert!(err.contains("truncated"), "want a truncation error, got: {err}");
        }
    }
}

#[test]
fn feeding_past_the_declared_length_is_rejected() {
    let bytes = encode(&edge_payloads()[0]);
    let mut dec = PartitionDecoder::new(bytes.len());
    dec.feed(&bytes).unwrap();
    let err = dec.feed(&[0]).expect_err("overfeed must error");
    assert!(err.contains("past the declared length"), "got: {err}");
}

#[test]
fn hostile_header_fields_error_without_allocating() {
    let payload = &edge_payloads()[4]; // the ragged wide-id cover shard
    let base = encode(payload);

    let mutate = |at: usize, to: u8| {
        let mut b = base.clone();
        b[at] = to;
        b
    };
    // Unknown family tags.
    for fam in [0u8, 5, 99, 255] {
        let err = PartitionPayload::decode_binary(&mutate(0, fam)).unwrap_err();
        assert!(err.contains("family"), "family {fam}: got {err}");
    }
    // Unknown flag bits on a cover payload; any flags on a modular one.
    let err = PartitionPayload::decode_binary(&mutate(1, 0x80)).unwrap_err();
    assert!(err.contains("flags"), "got {err}");
    let modular = encode(&edge_payloads()[3]);
    let mut b = modular.clone();
    b[1] = 1;
    let err = PartitionPayload::decode_binary(&b).unwrap_err();
    assert!(err.contains("flags"), "got {err}");
    // Wrong section counts.
    for n in [0u8, 2, 4, 255] {
        let err = PartitionPayload::decode_binary(&mutate(2, n)).unwrap_err();
        assert!(!err.is_empty(), "n_sections {n} must error");
    }
    // Nonzero reserved byte.
    let err = PartitionPayload::decode_binary(&mutate(3, 1)).unwrap_err();
    assert!(err.contains("reserved"), "got {err}");
    // Invalid section widths: 0, 3 and 16 are all outside {1, 2, 4, 8}.
    for (desc, w) in [(28, 0u8), (28, 3), (37, 16)] {
        let err = PartitionPayload::decode_binary(&mutate(desc, w)).unwrap_err();
        assert!(err.contains("width"), "width {w} at {desc}: got {err}");
    }
    // Oversized declared section length: the sum check must fire before
    // anything allocates, even when the length is absurd.
    let mut b = base.clone();
    b[20..28].copy_from_slice(&u64::MAX.to_le_bytes());
    b[28] = 1; // keep the width divisibility check satisfied
    let err = PartitionPayload::decode_binary(&b).unwrap_err();
    assert!(!err.is_empty(), "oversized length must error, not allocate");
    let mut b = base.clone();
    b[20..28].copy_from_slice(&(1u64 << 33).to_le_bytes());
    b[28] = 1;
    let err = PartitionPayload::decode_binary(&b).unwrap_err();
    assert!(err.contains("declares"), "got {err}");
}

#[test]
fn every_single_byte_flip_is_an_error_or_a_valid_payload_never_a_panic() {
    // The blanket no-panic sweep: a flipped byte may still decode (data
    // bytes are arbitrary), but it must never panic, hang, or allocate
    // beyond the buffer it was handed.
    for payload in battery(0xF1B, 6) {
        let bytes = encode(&payload);
        for at in 0..bytes.len() {
            for flip in [0x01u8, 0x80, 0xff] {
                let mut b = bytes.clone();
                b[at] ^= flip;
                let _ = PartitionPayload::decode_binary(&b);
            }
        }
    }
}

#[test]
fn mutated_frames_surface_as_dist_errors_at_the_wire_layer() {
    use greedyml::dist::wire::ToWorker;
    let cmd = ToWorker::InitPart {
        session: 5,
        machine: 0,
        threads: 1,
        payload: edge_payloads().remove(5),
    };
    let mut full = Vec::new();
    write_cmd(&mut full, &cmd, WireMode::Binary).unwrap();

    // Corrupt envelope tag: neither read path may panic.
    let mut b = full.clone();
    b[5] = 0x63;
    assert!(read_cmd(&mut b.as_slice()).is_err());
    assert!(read_session_init(&mut b.as_slice()).is_err());

    // Truncations across the whole frame (prefix, ctype, envelope,
    // payload): EOF inside the 4-byte length prefix is treated as a clean
    // frame boundary (Ok(None)); everything past it is a typed DistError
    // from both the buffered and the streaming reader.
    for cut in 0..full.len() {
        let b = &full[..cut];
        if cut < 4 {
            assert!(read_cmd(&mut &*b).unwrap().is_none());
            assert!(read_session_init(&mut &*b).unwrap().is_none());
        } else {
            read_cmd(&mut &*b).expect_err("truncated frame must error");
            read_session_init(&mut &*b).expect_err("truncated frame must error");
        }
    }

    // A length prefix promising more than the cap is refused up front.
    let mut b = full.clone();
    b[0..4].copy_from_slice(&(1u32 << 31).to_le_bytes());
    let err = read_cmd(&mut b.as_slice()).unwrap_err();
    assert!(err.to_string().contains("exceeds cap"), "got {err}");

    // A shortened length prefix leaves payload-header/frame disagreement
    // for the codec's sum check; a lengthened one starves the reader.
    let mut b = full.clone();
    b[0..4].copy_from_slice(&(full.len() as u32 - 5 - 4).to_le_bytes());
    b.truncate(full.len() - 4);
    assert!(read_cmd(&mut b.as_slice()).is_err());
    assert!(read_session_init(&mut b.as_slice()).is_err());

    // Flip every header/envelope byte of the frame: typed error or valid
    // decode, never a panic (buffered and streaming paths both).
    for at in 0..(full.len().min(64)) {
        for flip in [0x01u8, 0xff] {
            let mut b = full.clone();
            b[at] ^= flip;
            let _ = read_cmd(&mut b.as_slice());
            let _ = read_session_init(&mut b.as_slice());
        }
    }
}
