//! Tier-1 coverage for the two-level executor and the tiled/parallel gain
//! paths: the fanned-out `par_gain_batch` must match the serial per-element
//! loop for every oracle, and whole distributed runs must be bit-identical
//! across thread counts (the determinism contract of `dist::pool`).

use greedyml::algo::{run_greedyml, DistConfig};
use greedyml::constraint::Cardinality;
use greedyml::data::gen;
use greedyml::dist::pool;
use greedyml::objective::{
    FacilityLocation, KCover, KDominatingSet, KMedoid, Modular, Oracle, WeightedCover,
};
use greedyml::tree::AccumulationTree;
use std::sync::Arc;

/// One small instance of every CPU oracle.
fn all_oracles() -> Vec<Box<dyn Oracle>> {
    let itemsets = Arc::new(gen::transactions(
        gen::TransactionParams { num_sets: 300, num_items: 150, mean_size: 6.0, zipf_s: 0.9 },
        11,
    ));
    let weights: Vec<f64> = (0..150).map(|i| 1.0 + (i % 7) as f64 * 0.25).collect();
    let graph = Arc::new(gen::barabasi_albert(300, 3, 5));
    let (vs, _) = gen::gaussian_mixture(
        gen::GaussianParams { n: 200, dim: 24, classes: 4, noise: 0.4 },
        7,
    );
    vec![
        Box::new(KCover::new(itemsets.clone())),
        Box::new(WeightedCover::new(itemsets, weights).unwrap()),
        Box::new(KDominatingSet::new(graph)),
        Box::new(KMedoid::new(Arc::new(vs))),
        Box::new(FacilityLocation::random(40, 300, 9)),
        Box::new(Modular::random(300, 3)),
    ]
}

#[test]
fn par_gain_batch_matches_serial_loop_for_every_oracle() {
    for oracle in all_oracles() {
        let mut st = oracle.new_state(None);
        // A few commits so gains reflect a non-empty solution.
        for e in [3u32, 57, 120] {
            st.commit(e);
        }
        let cands: Vec<u32> = (0..oracle.n() as u32).collect();
        let serial: Vec<f64> = cands.iter().map(|&e| st.gain(e)).collect();
        let mut fanned = Vec::new();
        pool::with_pool(4, |_| pool::par_gain_batch(&*st, &cands, &mut fanned));
        assert_eq!(serial.len(), fanned.len(), "{}", oracle.name());
        for (i, (s, p)) in serial.iter().zip(&fanned).enumerate() {
            assert!(
                (s - p).abs() <= 1e-9,
                "{}: elem {i}: serial {s} vs parallel {p}",
                oracle.name()
            );
        }
    }
}

#[test]
fn par_gain_batch_is_chunk_count_invariant() {
    // The fan-out must produce the same bits whether the pool has 1, 2 or
    // many workers (chunk boundaries are fixed, never thread-derived).
    let (vs, _) = gen::gaussian_mixture(
        gen::GaussianParams { n: 300, dim: 16, classes: 4, noise: 0.3 },
        13,
    );
    let oracle = KMedoid::new(Arc::new(vs));
    let st = oracle.new_state(None);
    let cands: Vec<u32> = (0..300).collect();
    let mut reference = Vec::new();
    st.gain_batch(&cands, &mut reference);
    for threads in [1usize, 2, 4, 7] {
        let mut got = Vec::new();
        pool::with_pool(threads, |_| pool::par_gain_batch(&*st, &cands, &mut got));
        let same = reference
            .iter()
            .zip(&got)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(same, "threads={threads}: gains differ from serial reference");
    }
}

fn coverage_cfg(threads: Option<usize>) -> DistConfig {
    DistConfig { threads, ..DistConfig::greedyml(AccumulationTree::new(8, 2), 17) }
}

#[test]
fn run_greedyml_is_thread_count_invariant_on_coverage() {
    let data = gen::transactions(
        gen::TransactionParams { num_sets: 600, num_items: 300, mean_size: 6.0, zipf_s: 0.9 },
        23,
    );
    let o = KCover::new(Arc::new(data));
    let c = Cardinality::new(12);
    let base = run_greedyml(&o, &c, &coverage_cfg(Some(1))).unwrap();
    let auto = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    for threads in [4usize, auto] {
        let out = run_greedyml(&o, &c, &coverage_cfg(Some(threads))).unwrap();
        assert_eq!(base.solution, out.solution, "threads={threads}");
        assert_eq!(base.value.to_bits(), out.value.to_bits(), "threads={threads}");
        assert_eq!(base.total_calls, out.total_calls, "threads={threads}");
        assert_eq!(base.critical_calls, out.critical_calls, "threads={threads}");
    }
}

#[test]
fn run_greedyml_is_thread_count_invariant_on_kmedoid() {
    let (vs, _) = gen::gaussian_mixture(
        gen::GaussianParams { n: 400, dim: 16, classes: 5, noise: 0.4 },
        29,
    );
    let o = KMedoid::new(Arc::new(vs));
    let c = Cardinality::new(8);
    let mk = |threads: usize| DistConfig {
        local_view: true,
        threads: Some(threads),
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), 31)
    };
    let base = run_greedyml(&o, &c, &mk(1)).unwrap();
    let auto = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    for threads in [4usize, auto] {
        let out = run_greedyml(&o, &c, &mk(threads)).unwrap();
        assert_eq!(base.solution, out.solution, "threads={threads}");
        assert_eq!(base.value.to_bits(), out.value.to_bits(), "threads={threads}");
        assert_eq!(base.total_calls, out.total_calls, "threads={threads}");
    }
}

#[test]
fn lazy_greedy_inside_pool_matches_standalone() {
    // The level-two fan-out changes *where* gains are computed, never what
    // the algorithm selects.
    let data = gen::transactions(
        gen::TransactionParams { num_sets: 500, num_items: 250, mean_size: 7.0, zipf_s: 1.0 },
        3,
    );
    let o = KCover::new(Arc::new(data));
    let c = Cardinality::new(15);
    let cands: Vec<u32> = (0..500).collect();
    let standalone = greedyml::greedy::greedy_lazy(&o, &c, &cands, None);
    let pooled = pool::with_pool(4, |_| greedyml::greedy::greedy_lazy(&o, &c, &cands, None));
    assert_eq!(standalone.solution, pooled.solution);
    assert_eq!(standalone.calls, pooled.calls);
    assert_eq!(standalone.value.to_bits(), pooled.value.to_bits());
}
