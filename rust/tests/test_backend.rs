//! Backend parity: the thread backend (in-process pool, α–β-modeled comm),
//! the process backend (one forked worker per machine, measured comm) and
//! the tcp backend (worker sessions on `greedyml serve` daemons, measured
//! comm over real sockets) must produce **bit-identical** solutions,
//! values and call counts for the same seed and config — the backend only
//! decides *where* machines run, never *what* they compute.
//!
//! Problems are config-built (`coordinator::build_problem`) because the
//! process and tcp workers rebuild the oracle from the shipped problem
//! spec; the spec is the same text on both sides, so the data is
//! byte-identical.  The tcp tests spawn real `greedyml serve` daemons on
//! `127.0.0.1:0` and read the bound port back from their first stdout
//! line — the full multi-host path, no cluster needed.

use greedyml::algo::{
    run_dist, run_dist_pooled, run_dist_pooled_live, DistConfig, DistOutcome, PartitionScheme,
    SessionPool,
};
use greedyml::coordinator::{build_problem, experiment::build_constraint, problem_spec};
use greedyml::dist::wire::{read_frame, write_frame, FromWorker, ToWorker, PROTOCOL_VERSION};
use greedyml::dist::{BackendSpec, DistError, FaultSpec, ShipSpec, WireSpec};
use greedyml::tree::AccumulationTree;
use greedyml::util::config::Config;
use std::io::{BufRead, BufReader, BufWriter};
use std::net::TcpListener;
use std::process::{Child, Command, Stdio};

/// The real `greedyml` binary — the process backend's workers and the tcp
/// backend's `serve` daemons; the test binary itself has neither
/// subcommand.
fn worker_bin() -> String {
    env!("CARGO_BIN_EXE_greedyml").to_string()
}

/// One spawned `greedyml serve` daemon on an ephemeral localhost port,
/// killed on drop.
struct ServeDaemon {
    child: Child,
    addr: String,
}

impl ServeDaemon {
    fn spawn() -> Self {
        Self::spawn_env(&[])
    }

    /// Spawn with extra environment — how the fault-injection tests hand
    /// one specific daemon its `GREEDYML_FAULT_PLAN`.
    fn spawn_env(env: &[(&str, &str)]) -> Self {
        let mut cmd = Command::new(worker_bin());
        cmd.args(["serve", "--bind", "127.0.0.1:0"]).stdout(Stdio::piped());
        for (k, v) in env {
            cmd.env(k, v);
        }
        let mut child = cmd.spawn().expect("spawn greedyml serve");
        // The daemon's one stdout line: "greedyml serve: listening on <addr>".
        let mut line = String::new();
        BufReader::new(child.stdout.as_mut().expect("piped stdout"))
            .read_line(&mut line)
            .expect("read listen line");
        let addr = line
            .trim()
            .rsplit(' ')
            .next()
            .unwrap_or_default()
            .to_string();
        assert!(
            line.contains("listening on") && addr.contains(':'),
            "unexpected serve banner: {line:?}"
        );
        ServeDaemon { child, addr }
    }
}

impl Drop for ServeDaemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// A tcp-backend config targeting the given daemons.
fn tcp_cfg(cfg: &DistConfig, parsed: &Config, daemons: &[ServeDaemon]) -> DistConfig {
    DistConfig {
        backend: BackendSpec::Tcp,
        problem: Some(problem_spec(parsed)),
        hosts: Some(daemons.iter().map(|d| d.addr.clone()).collect()),
        ..cfg.clone()
    }
}

/// Run one config on both backends and return (thread, process) outcomes.
fn run_both(spec_text: &str, cfg: &DistConfig) -> (DistOutcome, DistOutcome) {
    let parsed = Config::parse(spec_text).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let thread_cfg = DistConfig { backend: BackendSpec::Thread, ..cfg.clone() };
    let process_cfg = DistConfig {
        backend: BackendSpec::Process,
        problem: Some(problem_spec(&parsed)),
        worker_bin: Some(worker_bin()),
        ..cfg.clone()
    };
    let a = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &thread_cfg)
        .expect("thread backend run");
    let b = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &process_cfg)
        .expect("process backend run");
    (a, b)
}

/// The bit-parity assertions shared by every workload.
fn assert_parity(thread: &DistOutcome, process: &DistOutcome) {
    assert_eq!(thread.solution, process.solution, "solutions must be bit-identical");
    assert_eq!(
        thread.value.to_bits(),
        process.value.to_bits(),
        "f(S) must survive the wire bit-exactly: {} vs {}",
        thread.value,
        process.value
    );
    assert_eq!(thread.critical_calls, process.critical_calls);
    assert_eq!(thread.total_calls, process.total_calls);
    assert_eq!(thread.max_accum_elems, process.max_accum_elems);
    assert_eq!(thread.machines.len(), process.machines.len());
    for (t, p) in thread.machines.iter().zip(&process.machines) {
        assert_eq!(t.id, p.id);
        assert_eq!(t.calls, p.calls, "machine {}", t.id);
        assert_eq!(t.cost, p.cost, "machine {}", t.id);
        assert_eq!(t.bytes_sent, p.bytes_sent, "machine {}", t.id);
        assert_eq!(t.bytes_received, p.bytes_received, "machine {}", t.id);
        assert_eq!(t.peak_mem, p.peak_mem, "machine {}", t.id);
        assert_eq!(t.top_level, p.top_level, "machine {}", t.id);
        assert_eq!(t.max_accum_elems, p.max_accum_elems, "machine {}", t.id);
    }
    // The meaning of the comm column differs: modeled vs measured.
    assert!(!thread.comm_measured, "thread backend models comm");
    assert!(process.comm_measured, "process backend measures comm");
}

const COVERAGE_SPEC: &str = "[dataset]\nkind = retail\nn = 500\nseed = 2\n[problem]\nk = 10\n";

#[test]
fn coverage_greedyml_tree_is_bit_identical_across_backends() {
    let cfg = DistConfig::greedyml(AccumulationTree::new(4, 2), 42);
    let (thread, process) = run_both(COVERAGE_SPEC, &cfg);
    assert_parity(&thread, &process);
    assert!(thread.value > 0.0);
    assert_eq!(thread.levels.len(), 3, "m=4, b=2 ⇒ 3 supersteps");
    // Real pipe transfers take nonzero wall time.
    assert!(process.comm_secs > 0.0, "measured comm must be positive");
}

#[test]
fn coverage_randgreedi_wide_tree_parity() {
    // b = m with RandGreeDI argmax semantics (compare_all_children) — the
    // ChildMsg values feed the argmax, so value transport is exercised.
    let cfg = DistConfig {
        compare_all_children: true,
        ..DistConfig::greedyml(AccumulationTree::randgreedi(6), 9)
    };
    let (thread, process) = run_both(COVERAGE_SPEC, &cfg);
    assert_parity(&thread, &process);
}

#[test]
fn greedi_contiguous_partition_parity() {
    // The GreeDI path: contiguous partition + argmax over all children.
    let cfg = DistConfig {
        partition: PartitionScheme::Contiguous,
        compare_all_children: true,
        ..DistConfig::greedyml(AccumulationTree::randgreedi(4), 0)
    };
    let (thread, process) = run_both(COVERAGE_SPEC, &cfg);
    assert_parity(&thread, &process);
}

#[test]
fn kmedoid_local_view_parity() {
    // k-medoid with the §6.4 machine-local evaluation views and added
    // elements: floats flow through gains, view re-evaluation and the
    // wire; everything must still match bit-for-bit.
    let spec = "[dataset]\nkind = gaussian\nn = 192\ndim = 12\nclasses = 6\nseed = 4\n\
                [problem]\nk = 8\n";
    let cfg = DistConfig {
        local_view: true,
        added_elements: 16,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), 7)
    };
    let (thread, process) = run_both(spec, &cfg);
    assert_parity(&thread, &process);
    assert!(thread.value > 0.0);
}

#[test]
fn oom_surfaces_identically_on_both_backends() {
    // A wide tree whose root must hold m−1 child solutions, with a limit
    // below its unconstrained peak: both backends must fail with the same
    // OutOfMemory coordinates (machine, level, label) — the process
    // backend carries the error across the wire.
    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let base = DistConfig {
        compare_all_children: true,
        ..DistConfig::greedyml(AccumulationTree::randgreedi(8), 3)
    };
    let probe = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &base).unwrap();
    let limit = probe.machines[0].peak_mem * 2 / 3;

    let thread_cfg = DistConfig {
        mem_limit: Some(limit),
        backend: BackendSpec::Thread,
        ..base.clone()
    };
    let process_cfg = DistConfig {
        mem_limit: Some(limit),
        backend: BackendSpec::Process,
        problem: Some(problem_spec(&parsed)),
        worker_bin: Some(worker_bin()),
        ..base
    };
    let te = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &thread_cfg).unwrap_err();
    let pe = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &process_cfg).unwrap_err();
    match (&te, &pe) {
        (
            DistError::OutOfMemory { machine: tm, level: tl, label: tla, .. },
            DistError::OutOfMemory { machine: pm, level: pl, label: pla, .. },
        ) => {
            assert_eq!(tm, pm, "same machine");
            assert_eq!(tl, pl, "same level");
            assert_eq!(tla, pla, "same allocation label");
        }
        other => panic!("expected twin OOMs, got {other:?}"),
    }
    assert_eq!(te, pe, "identical error payloads");
}

// ---- partition shipping (--ship partition) ------------------------------

/// Run one config on the thread backend and on the process backend with
/// partition shipping — workers receive O(n/m) shards instead of rebuild
/// recipes, and solutions travel with their data.
fn run_thread_and_partition(spec_text: &str, cfg: &DistConfig) -> (DistOutcome, DistOutcome) {
    let parsed = Config::parse(spec_text).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let thread_cfg = DistConfig { backend: BackendSpec::Thread, ..cfg.clone() };
    let process_cfg = DistConfig {
        backend: BackendSpec::Process,
        ship: ShipSpec::Partition,
        problem: Some(problem_spec(&parsed)),
        worker_bin: Some(worker_bin()),
        ..cfg.clone()
    };
    let a = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &thread_cfg)
        .expect("thread backend run");
    let b = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &process_cfg)
        .expect("partition-shipped process backend run");
    (a, b)
}

#[test]
fn partition_shipping_coverage_tree_is_bit_identical() {
    let cfg = DistConfig::greedyml(AccumulationTree::new(4, 2), 42);
    let (thread, part) = run_thread_and_partition(COVERAGE_SPEC, &cfg);
    assert_parity(&thread, &part);
    assert!(thread.value > 0.0);
}

#[test]
fn partition_shipping_graph_dominating_set_parity_with_added_elements() {
    // Graph data (adjacency shards over a global vertex universe) plus
    // §6.4 added elements — the coordinator must ship each machine the
    // extras its accumulation levels are seeded to draw.
    let spec = "[dataset]\nkind = ba\nn = 400\nattach = 3\nseed = 6\n[problem]\nk = 10\n";
    let cfg = DistConfig {
        added_elements: 24,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), 17)
    };
    let (thread, part) = run_thread_and_partition(spec, &cfg);
    assert_parity(&thread, &part);
    assert!(thread.value > 0.0);
}

#[test]
fn partition_shipping_kmedoid_local_view_parity() {
    // Floats through shard extraction, the JSON wire, the rebuilt local
    // VectorSet (fresh norms cache) and the tiled gain kernel — still
    // bit-identical to the thread backend.
    let spec = "[dataset]\nkind = gaussian\nn = 192\ndim = 12\nclasses = 6\nseed = 4\n\
                [problem]\nk = 8\n";
    let cfg = DistConfig {
        local_view: true,
        added_elements: 16,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), 7)
    };
    let (thread, part) = run_thread_and_partition(spec, &cfg);
    assert_parity(&thread, &part);
    assert!(thread.value > 0.0);
}

#[test]
fn partition_shipping_kmedoid_without_local_view_is_refused() {
    let spec = "[dataset]\nkind = gaussian\nn = 96\ndim = 8\nclasses = 4\nseed = 4\n\
                [problem]\nk = 4\n";
    let parsed = Config::parse(spec).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let cfg = DistConfig {
        backend: BackendSpec::Process,
        ship: ShipSpec::Partition,
        problem: Some(problem_spec(&parsed)),
        worker_bin: Some(worker_bin()),
        ..DistConfig::greedyml(AccumulationTree::new(2, 2), 1)
    };
    match run_dist(problem.oracle.as_ref(), constraint.as_ref(), &cfg).unwrap_err() {
        DistError::Backend { message } => {
            assert!(message.contains("local_view") || message.contains("local"), "{message}");
        }
        other => panic!("expected backend error, got {other:?}"),
    }
}

#[test]
fn init_shards_weigh_about_one_mth_of_the_full_dataset() {
    // The acceptance criterion in numbers: replay the run's partition
    // (RandomTape is deterministic in (n, m, seed)) and compare each
    // machine's Init shard against the spec-rebuilt footprint — the full
    // dataset extracted the same way.
    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let n = problem.oracle.n();
    let m = 4u32;
    let p = problem.oracle.partitionable().expect("k-cover is partitionable");
    let full = p.extract_partition(&(0..n as u32).collect::<Vec<_>>()).wire_bytes();
    let parts = greedyml::util::rng::RandomTape::draw(n, m, 42).partition();
    assert_eq!(parts.len(), m as usize);
    let mut total = 0usize;
    for part in &parts {
        let bytes = p.extract_partition(part).wire_bytes();
        assert!(
            bytes * (m as usize) < full * 2,
            "one of {m} shards weighs {bytes} bytes of a {full}-byte dataset"
        );
        total += bytes;
    }
    assert!(total >= full * 8 / 10, "shards together must carry the dataset");
}

#[test]
fn process_backend_single_machine_tree() {
    // Degenerate m = 1: one worker, no shipping at all.
    let cfg = DistConfig::greedyml(AccumulationTree::new(1, 2), 5);
    let (thread, process) = run_both(COVERAGE_SPEC, &cfg);
    assert_parity(&thread, &process);
    assert_eq!(process.comm_secs, 0.0, "no levels, no transfers");
}

// ---- tcp backend over localhost ----------------------------------------

/// Run one config on the thread backend and on the tcp backend over
/// `daemons` local `greedyml serve` processes; return both outcomes.
fn run_thread_and_tcp(
    spec_text: &str,
    cfg: &DistConfig,
    daemons: usize,
) -> (DistOutcome, DistOutcome) {
    let parsed = Config::parse(spec_text).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let fleet: Vec<ServeDaemon> = (0..daemons).map(|_| ServeDaemon::spawn()).collect();
    let thread_cfg = DistConfig { backend: BackendSpec::Thread, ..cfg.clone() };
    let a = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &thread_cfg)
        .expect("thread backend run");
    let b = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &tcp_cfg(cfg, &parsed, &fleet))
        .expect("tcp backend run");
    (a, b)
}

#[test]
fn tcp_coverage_parity_across_two_local_hosts() {
    // m = 4 machines placed round-robin on 2 daemons: every daemon hosts
    // two concurrent sessions, and the full GreedyML tree runs over real
    // sockets with the same bits as the in-process pool.
    let cfg = DistConfig::greedyml(AccumulationTree::new(4, 2), 42);
    let (thread, tcp) = run_thread_and_tcp(COVERAGE_SPEC, &cfg, 2);
    assert_parity(&thread, &tcp);
    assert!(thread.value > 0.0);
    assert!(tcp.comm_measured, "tcp backend measures comm");
    assert!(tcp.comm_secs > 0.0, "real socket transfers take nonzero wall time");
}

#[test]
fn tcp_kmedoid_local_view_parity() {
    // Floats through gains, §6.4 view re-evaluation and the socket —
    // bit-parity must survive all of it.
    let spec = "[dataset]\nkind = gaussian\nn = 192\ndim = 12\nclasses = 6\nseed = 4\n\
                [problem]\nk = 8\n";
    let cfg = DistConfig {
        local_view: true,
        added_elements: 16,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), 7)
    };
    let (thread, tcp) = run_thread_and_tcp(spec, &cfg, 2);
    assert_parity(&thread, &tcp);
    assert!(thread.value > 0.0);
}

#[test]
fn tcp_partition_shipping_parity_across_two_local_daemons() {
    // The satellite case from the issue: `--ship partition` over real
    // sockets to two `greedyml serve` daemons, m = 4 machines placed
    // round-robin — shards out, data-carrying solutions up the tree, and
    // the final solution/value bit-identical to the thread backend.
    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let fleet = vec![ServeDaemon::spawn(), ServeDaemon::spawn()];
    let cfg = DistConfig::greedyml(AccumulationTree::new(4, 2), 42);
    let thread_cfg = DistConfig { backend: BackendSpec::Thread, ..cfg.clone() };
    let a = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &thread_cfg)
        .expect("thread backend run");
    let tcp = DistConfig { ship: ShipSpec::Partition, ..tcp_cfg(&cfg, &parsed, &fleet) };
    let b = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &tcp)
        .expect("partition-shipped tcp run");
    assert_parity(&a, &b);
    assert!(b.comm_secs > 0.0, "shard-carrying gathers take nonzero wall time");
}

#[test]
fn tcp_oom_coordinates_cross_the_wire_identically() {
    // The twin-OOM property of the process backend, now over sockets: a
    // wide tree whose root cannot hold m−1 child solutions must die with
    // the same (machine, level, label) on both backends.
    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let base = DistConfig {
        compare_all_children: true,
        ..DistConfig::greedyml(AccumulationTree::randgreedi(8), 3)
    };
    let probe = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &base).unwrap();
    let limit = probe.machines[0].peak_mem * 2 / 3;

    let thread_cfg = DistConfig {
        mem_limit: Some(limit),
        backend: BackendSpec::Thread,
        ..base.clone()
    };
    let te = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &thread_cfg).unwrap_err();

    let fleet = vec![ServeDaemon::spawn(), ServeDaemon::spawn()];
    let limited = DistConfig { mem_limit: Some(limit), ..base };
    let tcp = tcp_cfg(&limited, &parsed, &fleet);
    let pe = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &tcp).unwrap_err();
    assert_eq!(te, pe, "identical OOM payloads across thread and tcp");
    match pe {
        DistError::OutOfMemory { machine, level, .. } => {
            assert_eq!(machine, 0, "root is the bottleneck");
            assert_eq!(level, 1);
        }
        other => panic!("expected OOM, got {other:?}"),
    }
}

#[test]
fn tcp_worker_death_mid_superstep_is_an_error_not_a_hang() {
    // A scripted rogue worker: completes the handshake, the session Init
    // and the Job ack, then drops the connection at the Leaf command —
    // exactly what a crashed or OOM-killed remote host looks like.  The
    // coordinator must fail with a retryable DistError::Transport (under
    // the default fail policy nothing retries it) instead of blocking
    // forever.
    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let n = problem.oracle.n();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let rogue = std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut input = BufReader::new(stream.try_clone().unwrap());
        let mut output = BufWriter::new(stream);
        let hello = read_frame(&mut input).unwrap().expect("hello frame");
        match ToWorker::from_value(&hello).unwrap() {
            ToWorker::Hello { version } => assert_eq!(version, PROTOCOL_VERSION),
            other => panic!("expected hello, got {other:?}"),
        }
        write_frame(&mut output, &FromWorker::Welcome { version: PROTOCOL_VERSION }.to_value())
            .unwrap();
        let init = read_frame(&mut input).unwrap().expect("init frame");
        match ToWorker::from_value(&init).unwrap() {
            ToWorker::Init { .. } => {}
            other => panic!("expected init, got {other:?}"),
        }
        write_frame(&mut output, &FromWorker::Ready { n }.to_value()).unwrap();
        let job = read_frame(&mut input).unwrap().expect("job frame");
        match ToWorker::from_value(&job).unwrap() {
            ToWorker::Job { .. } => {}
            other => panic!("expected job, got {other:?}"),
        }
        write_frame(&mut output, &FromWorker::Ready { n }.to_value()).unwrap();
        // Read the Leaf command, then die without replying.
        let _ = read_frame(&mut input);
    });

    let cfg = DistConfig {
        backend: BackendSpec::Tcp,
        problem: Some(problem_spec(&parsed)),
        hosts: Some(vec![addr]),
        ..DistConfig::greedyml(AccumulationTree::new(1, 2), 1)
    };
    let err = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &cfg).unwrap_err();
    assert!(err.is_retryable(), "worker death is retryable: {err}");
    match err {
        DistError::Transport { message } => {
            assert!(message.contains("disconnected"), "{message}");
        }
        other => panic!("expected transport error, got {other:?}"),
    }
    rogue.join().unwrap();
}

#[test]
fn tcp_daemon_survives_across_runs() {
    // One daemon, two complete back-to-back runs: sessions are per-run,
    // the daemon is long-lived infrastructure.
    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let fleet = vec![ServeDaemon::spawn()];
    let cfg = DistConfig::greedyml(AccumulationTree::new(2, 2), 11);
    let tcp = tcp_cfg(&cfg, &parsed, &fleet);
    let a = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &tcp).expect("first run");
    let b = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &tcp).expect("second run");
    assert_eq!(a.solution, b.solution);
    assert_eq!(a.value.to_bits(), b.value.to_bits());
}

// ---- resident-shard sessions (warm fleets) ------------------------------

#[test]
fn warm_process_fleet_matches_cold_and_thread_bit_for_bit() {
    // One process fleet answers two jobs with different k; each job must
    // be bit-identical to a cold fleet (fresh workers, full Init) and to
    // the thread backend — a warm session changes shipping cost only,
    // never results.
    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let pool = SessionPool::new();
    for (i, k) in [6usize, 10].into_iter().enumerate() {
        let spec = format!("{}problem.k = {k}\n", problem_spec(&parsed));
        let spec_cfg = Config::parse(&spec).unwrap();
        let (constraint, _) = build_constraint(&spec_cfg, problem.oracle.n()).unwrap();
        let cfg = DistConfig {
            backend: BackendSpec::Process,
            problem: Some(spec),
            worker_bin: Some(worker_bin()),
            ..DistConfig::greedyml(AccumulationTree::new(4, 2), 42)
        };
        let pooled = run_dist_pooled(problem.oracle.as_ref(), constraint.as_ref(), &cfg, &pool)
            .expect("pooled run");
        assert_eq!(pool.last_was_warm(), i > 0, "first job establishes, later jobs reuse");
        let cold = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &cfg).expect("cold run");
        let thread_cfg = DistConfig { backend: BackendSpec::Thread, ..cfg.clone() };
        let thread = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &thread_cfg)
            .expect("thread run");
        assert_parity(&thread, &pooled);
        assert_parity(&thread, &cold);
    }
    assert_eq!(pool.sessions_established(), 1, "one fleet answers both jobs");
    assert_eq!(pool.jobs_run(), 2);
    assert_eq!(pool.warm_jobs(), 1);
}

#[test]
fn warm_tcp_partition_fleet_ships_shards_once_and_stays_bit_identical() {
    // The acceptance case over real sockets: partition-shipped shards go
    // out when the session is established and never again — later jobs
    // add zero Init bytes — while every job stays bit-identical to a
    // cold fleet and to the thread backend.
    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let fleet: Vec<ServeDaemon> = (0..2).map(|_| ServeDaemon::spawn()).collect();
    let pool = SessionPool::new();
    let mut shipped_once = 0u64;
    for (i, k) in [6usize, 10].into_iter().enumerate() {
        let spec = format!("{}problem.k = {k}\n", problem_spec(&parsed));
        let spec_cfg = Config::parse(&spec).unwrap();
        let (constraint, _) = build_constraint(&spec_cfg, problem.oracle.n()).unwrap();
        let base = DistConfig::greedyml(AccumulationTree::new(4, 2), 42);
        let cfg = DistConfig {
            ship: ShipSpec::Partition,
            problem: Some(spec),
            ..tcp_cfg(&base, &parsed, &fleet)
        };
        let pooled = run_dist_pooled(problem.oracle.as_ref(), constraint.as_ref(), &cfg, &pool)
            .expect("warm tcp run");
        if i == 0 {
            shipped_once = pool.init_bytes_total();
            assert!(shipped_once > 0, "establishing ships the shards");
        }
        assert_eq!(pool.init_bytes_total(), shipped_once, "later jobs re-ship nothing");
        let cold = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &cfg)
            .expect("cold tcp run");
        let thread_cfg = DistConfig { backend: BackendSpec::Thread, ..cfg.clone() };
        let thread = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &thread_cfg)
            .expect("thread run");
        assert_parity(&thread, &pooled);
        assert_parity(&thread, &cold);
    }
    assert_eq!(pool.sessions_established(), 1, "both jobs share one resident session");
}

#[test]
fn tcp_daemon_death_between_jobs_poisons_the_session_and_the_pool_recovers() {
    // A daemon dies while its fleet sits warm between jobs.  The next
    // submission must fail cleanly (no hang), the poisoned session must
    // leave the pool, and a fresh fleet must serve the same query again
    // with the same bits.
    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let pool = SessionPool::new();
    let base = DistConfig::greedyml(AccumulationTree::new(2, 2), 11);

    let mut daemons = vec![ServeDaemon::spawn()];
    let cfg = tcp_cfg(&base, &parsed, &daemons);
    let first = run_dist_pooled(problem.oracle.as_ref(), constraint.as_ref(), &cfg, &pool)
        .expect("first job");
    assert_eq!(pool.sessions_established(), 1);

    daemons[0].child.kill().unwrap();
    daemons[0].child.wait().unwrap();

    let err = run_dist_pooled(problem.oracle.as_ref(), constraint.as_ref(), &cfg, &pool)
        .expect_err("a dead resident session must error, not hang");
    assert!(matches!(err, DistError::Transport { .. }), "{err}");
    assert_eq!(pool.jobs_run(), 2);
    assert_eq!(pool.warm_jobs(), 0, "the failed reuse is not a warm job");

    let daemons = vec![ServeDaemon::spawn()];
    let cfg = tcp_cfg(&base, &parsed, &daemons);
    let third = run_dist_pooled(problem.oracle.as_ref(), constraint.as_ref(), &cfg, &pool)
        .expect("recovered job on a fresh fleet");
    assert_eq!(pool.sessions_established(), 2, "recovery re-establishes from scratch");
    assert_eq!(third.solution, first.solution);
    assert_eq!(third.value.to_bits(), first.value.to_bits());
}

// ---- binary wire (--wire binary, protocol v5) ---------------------------

#[test]
fn binary_wire_matches_json_and_thread_across_process_and_tcp() {
    // The v5 cross-format parity matrix: {process, tcp} × {json, binary}
    // under partition shipping, every cell bit-identical to the thread
    // backend (and hence to every other cell) — the frame encoding
    // decides bytes on the wire, never results.
    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let base = DistConfig::greedyml(AccumulationTree::new(4, 2), 42);
    let thread_cfg = DistConfig { backend: BackendSpec::Thread, ..base.clone() };
    let thread = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &thread_cfg)
        .expect("thread backend run");
    let fleet: Vec<ServeDaemon> = (0..2).map(|_| ServeDaemon::spawn()).collect();
    for wire in [WireSpec::Json, WireSpec::Binary] {
        let process_cfg = DistConfig {
            backend: BackendSpec::Process,
            ship: ShipSpec::Partition,
            problem: Some(problem_spec(&parsed)),
            worker_bin: Some(worker_bin()),
            wire,
            ..base.clone()
        };
        let process = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &process_cfg)
            .unwrap_or_else(|e| panic!("process backend under {wire:?}: {e}"));
        assert_parity(&thread, &process);
        let tcp =
            DistConfig { ship: ShipSpec::Partition, wire, ..tcp_cfg(&base, &parsed, &fleet) };
        let tcp_out = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &tcp)
            .unwrap_or_else(|e| panic!("tcp backend under {wire:?}: {e}"));
        assert_parity(&thread, &tcp_out);
    }
}

#[test]
fn binary_wire_spec_shipping_and_kmedoid_floats_stay_bit_identical() {
    // Binary framing must be inert under spec shipping (only shipped
    // solutions change encoding) and bit-exact for the float-heavy
    // k-medoid local-view path under partition shipping.
    let cfg = DistConfig {
        wire: WireSpec::Binary,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), 42)
    };
    let (thread, process) = run_both(COVERAGE_SPEC, &cfg);
    assert_parity(&thread, &process);

    let spec = "[dataset]\nkind = gaussian\nn = 192\ndim = 12\nclasses = 6\nseed = 4\n\
                [problem]\nk = 8\n";
    let cfg = DistConfig {
        local_view: true,
        added_elements: 16,
        wire: WireSpec::Binary,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), 7)
    };
    let (thread, part) = run_thread_and_partition(spec, &cfg);
    assert_parity(&thread, &part);
    assert!(thread.value > 0.0);
}

#[test]
fn warm_fleet_reuse_under_binary_wire_and_json_jobs_get_a_separate_fleet() {
    // A fleet speaks the wire mode it was established with for its whole
    // lifetime: two binary jobs share one resident session, while a json
    // job — same problem, same tree — must establish its own fleet.  All
    // three stay bit-identical to the thread backend.
    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let pool = SessionPool::new();
    let jobs = [(6usize, WireSpec::Binary), (10, WireSpec::Binary), (10, WireSpec::Json)];
    for (i, (k, wire)) in jobs.into_iter().enumerate() {
        let spec = format!("{}problem.k = {k}\n", problem_spec(&parsed));
        let spec_cfg = Config::parse(&spec).unwrap();
        let (constraint, _) = build_constraint(&spec_cfg, problem.oracle.n()).unwrap();
        let cfg = DistConfig {
            backend: BackendSpec::Process,
            ship: ShipSpec::Partition,
            problem: Some(spec),
            worker_bin: Some(worker_bin()),
            wire,
            ..DistConfig::greedyml(AccumulationTree::new(4, 2), 42)
        };
        let pooled = run_dist_pooled(problem.oracle.as_ref(), constraint.as_ref(), &cfg, &pool)
            .expect("pooled run");
        assert_eq!(pool.last_was_warm(), i == 1, "only the second binary job reuses a fleet");
        let thread_cfg = DistConfig { backend: BackendSpec::Thread, ..cfg.clone() };
        let thread = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &thread_cfg)
            .expect("thread run");
        assert_parity(&thread, &pooled);
    }
    assert_eq!(pool.sessions_established(), 2, "binary and json fleets never mix");
    assert_eq!(pool.jobs_run(), 3);
    assert_eq!(pool.warm_jobs(), 1);
}

#[test]
fn tcp_retry_revives_a_killed_binary_session_bit_identically() {
    // `--on-fault retry` under `--wire binary`: machine 1 lands on the
    // doomed daemon (round-robin placement), whose plan kills the session
    // at its Leaf command.  The supervisor dials the next host and
    // replays the command log — the binary init_part frame included — and
    // the run must end bit-identical to the fault-free thread backend.
    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let base = DistConfig::greedyml(AccumulationTree::new(4, 2), 42);
    let thread_cfg = DistConfig { backend: BackendSpec::Thread, ..base.clone() };
    let thread = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &thread_cfg)
        .expect("thread run");
    let daemons = [
        ServeDaemon::spawn(),
        ServeDaemon::spawn_env(&[("GREEDYML_FAULT_PLAN", "kill:m1@leaf")]),
    ];
    let cfg = DistConfig {
        ship: ShipSpec::Partition,
        wire: WireSpec::Binary,
        on_fault: FaultSpec::Retry,
        ..tcp_cfg(&base, &parsed, &daemons)
    };
    let retried = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &cfg)
        .expect("supervised binary tcp run");
    assert_eq!(retried.solution, thread.solution, "revival must not change the answer");
    assert_eq!(retried.value.to_bits(), thread.value.to_bits());
    assert_eq!(retried.critical_calls, thread.critical_calls);
    assert_eq!(retried.total_calls, thread.total_calls);
    assert!(retried.faults.faults_seen >= 1, "{:?}", retried.faults);
    assert!(retried.faults.retries >= 1, "{:?}", retried.faults);
    assert!(retried.faults.machines_dropped.is_empty(), "retry drops nobody");
}

#[test]
fn bad_problem_spec_is_a_backend_error_not_a_hang() {
    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let cfg = DistConfig {
        backend: BackendSpec::Process,
        problem: Some("dataset.kind = not_a_dataset\n".to_string()),
        worker_bin: Some(worker_bin()),
        ..DistConfig::greedyml(AccumulationTree::new(2, 2), 1)
    };
    match run_dist(problem.oracle.as_ref(), constraint.as_ref(), &cfg).unwrap_err() {
        DistError::Backend { message } => {
            assert!(
                message.contains("not_a_dataset") || message.contains("unknown"),
                "{message}"
            );
        }
        other => panic!("expected backend error, got {other:?}"),
    }
}

// ---- live-epoch sessions (stale-fleet handling) --------------------------

#[test]
fn stale_epoch_fleets_advance_one_step_and_are_evicted_beyond_that() {
    // The pool keys resident fleets by (dataset fingerprint, epoch), so a
    // pre-delta fleet never key-matches a post-delta job.  Exactly one
    // epoch behind it is advanced in place (no re-establish); any staler
    // it must leave the pool and the session is rebuilt cold — and either
    // way the answer equals a cold solve of the post-delta corpus.
    use greedyml::objective::PartitionDelta;
    use greedyml::stream::LiveProblem;

    let parsed = Config::parse(COVERAGE_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let mut live = LiveProblem::new(problem.oracle.as_ref()).unwrap();
    let p = problem.oracle.partitionable().unwrap();
    let del_only = |dels: &[u32]| -> PartitionDelta {
        let mut insert = p.extract_partition(&[]);
        insert.n_global = 500;
        PartitionDelta { n_global: 500, insert, delete: dels.to_vec() }
    };
    let pool = SessionPool::new();
    let cfg_at = |epoch: u64| DistConfig {
        backend: BackendSpec::Process,
        ship: ShipSpec::Partition,
        problem: Some(problem_spec(&parsed)),
        worker_bin: Some(worker_bin()),
        epoch,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), 42)
    };
    run_dist_pooled_live(live.oracle(), constraint.as_ref(), &cfg_at(0), &pool, Some(&live))
        .expect("epoch-0 run");
    assert_eq!(pool.sessions_established(), 1);

    // One epoch behind: advanced in place.
    live.apply(&del_only(&[7, 99])).unwrap();
    let one =
        run_dist_pooled_live(live.oracle(), constraint.as_ref(), &cfg_at(1), &pool, Some(&live))
            .expect("one-behind re-solve");
    assert!(one.warm, "a fleet exactly one epoch behind is advanced, not evicted");
    assert_eq!(pool.sessions_established(), 1, "advancing never re-establishes");

    // Two epochs behind: evicted, re-established cold.
    live.apply(&del_only(&[123])).unwrap();
    live.apply(&del_only(&[256, 400])).unwrap();
    let jump =
        run_dist_pooled_live(live.oracle(), constraint.as_ref(), &cfg_at(3), &pool, Some(&live))
            .expect("two-behind re-solve");
    assert!(!jump.warm, "a multi-epoch-stale fleet is released, never fast-forwarded");
    assert_eq!(pool.sessions_established(), 2, "the stale fleet left the pool");

    let cold_pool = SessionPool::new();
    let cold = run_dist_pooled_live(
        live.oracle(),
        constraint.as_ref(),
        &cfg_at(3),
        &cold_pool,
        Some(&live),
    )
    .expect("cold control");
    assert_eq!(jump.outcome.solution, cold.outcome.solution);
    assert_eq!(jump.outcome.value.to_bits(), cold.outcome.value.to_bits());

    // A job still addressed at a pre-delta epoch is refused outright — no
    // cached fleet (or cached answer) may serve it silently.
    let err =
        run_dist_pooled_live(live.oracle(), constraint.as_ref(), &cfg_at(0), &pool, Some(&live))
            .expect_err("stale-epoch job must be rejected");
    assert!(err.to_string().contains("epoch"), "{err}");
}
