//! The streaming subsystem's acceptance matrix: live-dataset deltas and
//! sieve-coreset mode over real remote fleets.
//!
//! The delta half pins the determinism contract — a warm fleet advanced
//! **in place** by a `delta` frame answers the next solve bit-identically
//! to a cold fleet shipped the post-delta dataset from scratch — across
//! {process, tcp} × {json, binary}.  The coreset half pins that a
//! `--coreset on` run is bit-identical across backends and wire modes and
//! keeps the sieve's (1/2 − ε) band against the full-shard answer.

use greedyml::algo::{run_dist, run_dist_pooled_live, DistConfig, SessionPool};
use greedyml::constraint::Cardinality;
use greedyml::coordinator::{build_problem, experiment::build_constraint, problem_spec};
use greedyml::data::gen::{transactions, TransactionParams};
use greedyml::dist::{BackendSpec, CoresetSpec, ShipSpec, WireSpec};
use greedyml::objective::{KCover, Oracle, PartitionDelta, PartitionOracle};
use greedyml::stream::{LiveProblem, CORESET_EPSILON};
use greedyml::tree::AccumulationTree;
use greedyml::util::config::Config;
use greedyml::util::rng::RandomTape;
use greedyml::ElemId;
use std::io::BufRead;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;

/// The real `greedyml` binary — process-backend workers and `serve`
/// daemons both come from it.
fn worker_bin() -> String {
    env!("CARGO_BIN_EXE_greedyml").to_string()
}

/// One spawned `greedyml serve` daemon on an ephemeral port, killed on
/// drop (same helper as test_backend.rs).
struct ServeDaemon {
    child: Child,
    addr: String,
}

impl ServeDaemon {
    fn spawn() -> Self {
        let mut child = Command::new(worker_bin())
            .args(["serve", "--bind", "127.0.0.1:0"])
            .stdout(Stdio::piped())
            .spawn()
            .expect("spawn greedyml serve");
        let mut line = String::new();
        std::io::BufReader::new(child.stdout.as_mut().expect("piped stdout"))
            .read_line(&mut line)
            .expect("read listen line");
        let addr = line.trim().rsplit(' ').next().unwrap_or_default().to_string();
        assert!(line.contains("listening on") && addr.contains(':'), "{line:?}");
        ServeDaemon { child, addr }
    }
}

impl Drop for ServeDaemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

// ---- live-dataset fixture ----------------------------------------------

/// Epoch-0 ground-set size of the live fixture.
const N0: usize = 520;
/// Spec the pool fingerprints the corpus under.  Partition shipping never
/// rebuilds from it — shards come from the live oracle — so it only has
/// to be stable parseable text.
const LIVE_SPEC: &str = "dataset.kind = retail\ndataset.n = 520\n";
const SEED: u64 = 42;
const K: usize = 10;

/// A delta over the `grown` super-dataset: `fresh` ids (beyond the live
/// oracle's horizon) arrive with their real data rows, `dels` leave.
fn delta_from(
    grown: &KCover,
    n_global: usize,
    fresh: &[ElemId],
    dels: &[ElemId],
) -> PartitionDelta {
    let mut insert = grown.partitionable().unwrap().extract_partition(fresh);
    insert.n_global = n_global;
    PartitionDelta { n_global, insert, delete: dels.to_vec() }
}

/// The live fixture: a 520-element epoch-0 dataset carved out of a
/// 560-element "future" dataset, plus two deltas that insert the later
/// elements (with data) and delete earlier ones — the second delta also
/// deletes an element the first inserted.
fn live_fixture() -> (LiveProblem, Vec<PartitionDelta>) {
    let grown = KCover::new(Arc::new(transactions(
        TransactionParams { num_sets: 560, num_items: 240, mean_size: 6.0, zipf_s: 0.9 },
        13,
    )));
    let p = grown.partitionable().unwrap();
    let base_ids: Vec<ElemId> = (0..N0 as u32).collect();
    let mut base = p.extract_partition(&base_ids);
    base.n_global = N0;
    let live = LiveProblem::from_oracle(PartitionOracle::from_payload(&base).unwrap());
    let d1 = delta_from(&grown, 536, &(520u32..536).collect::<Vec<_>>(), &[3, 17, 101, 250]);
    let d2 = delta_from(&grown, 549, &(536u32..549).collect::<Vec<_>>(), &[9, 333, 520]);
    (live, vec![d1, d2])
}

/// A partition-shipped process-backend config at `epoch`.
fn process_cfg(epoch: u64, wire: WireSpec) -> DistConfig {
    DistConfig {
        backend: BackendSpec::Process,
        ship: ShipSpec::Partition,
        problem: Some(LIVE_SPEC.to_string()),
        worker_bin: Some(worker_bin()),
        wire,
        epoch,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), SEED)
    }
}

/// The same config over tcp daemons.
fn tcp_live_cfg(epoch: u64, wire: WireSpec, fleet: &[ServeDaemon]) -> DistConfig {
    DistConfig {
        backend: BackendSpec::Tcp,
        hosts: Some(fleet.iter().map(|d| d.addr.clone()).collect()),
        worker_bin: None,
        ..process_cfg(epoch, wire)
    }
}

/// The shared delta-replay assertion: establish at epoch 0, then after
/// every delta (a) advance the warm fleet in place and (b) cold-solve the
/// post-delta dataset on a fresh pool — both answers must agree
/// bit-for-bit, and the warm pool must never re-establish.
fn assert_incremental_matches_cold(cfg_at: impl Fn(u64) -> DistConfig) {
    let (mut live, deltas) = live_fixture();
    let c = Cardinality::new(K);
    let warm_pool = SessionPool::new();
    let r0 = run_dist_pooled_live(live.oracle(), &c, &cfg_at(0), &warm_pool, Some(&live))
        .expect("epoch-0 run");
    assert!(!r0.warm, "first run establishes");
    assert!(r0.outcome.value > 0.0);
    assert_eq!(warm_pool.sessions_established(), 1);
    for (i, d) in deltas.iter().enumerate() {
        live.apply(d).unwrap();
        let cfg = cfg_at(live.epoch());
        let inc = run_dist_pooled_live(live.oracle(), &c, &cfg, &warm_pool, Some(&live))
            .unwrap_or_else(|e| panic!("incremental re-solve after delta {i}: {e}"));
        assert!(inc.warm, "delta {i}: a one-epoch-behind fleet advances in place");
        assert_eq!(
            warm_pool.sessions_established(),
            1,
            "delta {i}: advancing must not re-establish the session"
        );
        let cold_pool = SessionPool::new();
        let cold = run_dist_pooled_live(live.oracle(), &c, &cfg, &cold_pool, Some(&live))
            .unwrap_or_else(|e| panic!("cold re-solve after delta {i}: {e}"));
        assert!(!cold.warm);
        assert_eq!(
            inc.outcome.solution, cold.outcome.solution,
            "delta {i}: incremental and cold solutions must be bit-identical"
        );
        assert_eq!(
            inc.outcome.value.to_bits(),
            cold.outcome.value.to_bits(),
            "delta {i}: {} vs {}",
            inc.outcome.value,
            cold.outcome.value
        );
        assert_eq!(inc.outcome.total_calls, cold.outcome.total_calls, "delta {i}");
        assert!(inc.outcome.value > 0.0);
    }
}

#[test]
fn process_incremental_delta_resolve_is_bit_identical_to_cold_json_and_binary() {
    for wire in [WireSpec::Json, WireSpec::Binary] {
        assert_incremental_matches_cold(|epoch| process_cfg(epoch, wire));
    }
}

#[test]
fn tcp_incremental_delta_resolve_is_bit_identical_to_cold_json_and_binary() {
    for wire in [WireSpec::Json, WireSpec::Binary] {
        let fleet: Vec<ServeDaemon> = (0..2).map(|_| ServeDaemon::spawn()).collect();
        assert_incremental_matches_cold(|epoch| tcp_live_cfg(epoch, wire, &fleet));
    }
}

#[test]
fn incremental_resolve_matches_a_thread_rerun_on_the_replayed_partition() {
    // The thread backend has no fleet to advance — it just re-solves over
    // the post-delta oracle on the replayed leaf partition.  By the
    // determinism contract that is the same answer the advanced remote
    // fleet gives.
    let (mut live, deltas) = live_fixture();
    let c = Cardinality::new(K);
    let pool = SessionPool::new();
    run_dist_pooled_live(live.oracle(), &c, &process_cfg(0, WireSpec::Json), &pool, Some(&live))
        .expect("epoch-0 run");
    live.apply(&deltas[0]).unwrap();
    let inc =
        run_dist_pooled_live(live.oracle(), &c, &process_cfg(1, WireSpec::Json), &pool, Some(&live))
            .expect("incremental re-solve");
    assert!(inc.warm);
    let base = RandomTape::draw(live.n0(), 4, SEED).partition();
    let thread_cfg = DistConfig {
        backend: BackendSpec::Thread,
        parts: Some(live.parts_for(base, SEED)),
        epoch: 1,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), SEED)
    };
    let thread = run_dist(live.oracle(), &c, &thread_cfg).expect("thread re-solve");
    assert_eq!(inc.outcome.solution, thread.solution);
    assert_eq!(inc.outcome.value.to_bits(), thread.value.to_bits());
    // The pooled-live thread path pins the same replay on its own — a
    // caller who never touches `parts` (the CLI's `--backend thread
    // --deltas` cell) still gets the resident-shard split, not a fresh
    // draw over an id space that contains the deleted elements.
    let auto_cfg = DistConfig {
        backend: BackendSpec::Thread,
        epoch: 1,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), SEED)
    };
    let auto =
        run_dist_pooled_live(live.oracle(), &c, &auto_cfg, &SessionPool::new(), Some(&live))
            .expect("pooled-live thread re-solve");
    assert!(!auto.warm);
    assert_eq!(auto.outcome.solution, thread.solution);
    assert_eq!(auto.outcome.value.to_bits(), thread.value.to_bits());
}

#[test]
fn deleted_and_inserted_elements_actually_move_the_answer() {
    // Guard against a vacuous fixture: the deltas must change the dataset
    // enough that at least one post-delta solution differs from the
    // epoch-0 one, or every parity cell above would pass trivially.
    let (mut live, deltas) = live_fixture();
    let c = Cardinality::new(K);
    let base_parts = RandomTape::draw(live.n0(), 4, SEED).partition();
    let cfg = DistConfig {
        backend: BackendSpec::Thread,
        parts: Some(live.parts_for(base_parts.clone(), SEED)),
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), SEED)
    };
    let before = run_dist(live.oracle(), &c, &cfg).unwrap();
    for d in &deltas {
        live.apply(d).unwrap();
    }
    for d in &deltas {
        for &e in &d.delete {
            assert!(!live.oracle().holds(e), "deleted element {e} still held");
        }
    }
    let cfg = DistConfig {
        parts: Some(live.parts_for(base_parts, SEED)),
        epoch: live.epoch(),
        ..cfg
    };
    let after = run_dist(live.oracle(), &c, &cfg).unwrap();
    assert!(
        after.solution != before.solution || after.value.to_bits() != before.value.to_bits(),
        "deltas did not perturb the solve at all — fixture too weak"
    );
}

// ---- coreset mode -------------------------------------------------------

const CORESET_SPEC: &str = "[dataset]\nkind = retail\nn = 500\nseed = 2\n[problem]\nk = 10\n";

#[test]
fn coreset_runs_are_bit_identical_across_backends_and_keep_the_sieve_band() {
    let parsed = Config::parse(CORESET_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let base = DistConfig::greedyml(AccumulationTree::new(4, 2), SEED);

    let full_cfg = DistConfig { backend: BackendSpec::Thread, ..base.clone() };
    let full = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &full_cfg)
        .expect("full thread run");
    let cs_cfg = DistConfig { coreset: CoresetSpec::On, ..full_cfg };
    let cs = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &cs_cfg)
        .expect("coreset thread run");
    assert!(cs.value > 0.0);
    assert!(
        cs.value >= (0.5 - CORESET_EPSILON) * full.value,
        "coreset value {} fell out of the sieve band of the full value {}",
        cs.value,
        full.value
    );

    let fleet: Vec<ServeDaemon> = (0..2).map(|_| ServeDaemon::spawn()).collect();
    for wire in [WireSpec::Json, WireSpec::Binary] {
        let process = DistConfig {
            backend: BackendSpec::Process,
            ship: ShipSpec::Partition,
            problem: Some(problem_spec(&parsed)),
            worker_bin: Some(worker_bin()),
            wire,
            coreset: CoresetSpec::On,
            ..base.clone()
        };
        let p = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &process)
            .unwrap_or_else(|e| panic!("process coreset run under {wire:?}: {e}"));
        assert_eq!(p.solution, cs.solution, "process {wire:?}");
        assert_eq!(p.value.to_bits(), cs.value.to_bits(), "process {wire:?}");
        assert_eq!(p.total_calls, cs.total_calls, "process {wire:?}");

        let tcp = DistConfig {
            backend: BackendSpec::Tcp,
            hosts: Some(fleet.iter().map(|d| d.addr.clone()).collect()),
            worker_bin: None,
            ..process
        };
        let t = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &tcp)
            .unwrap_or_else(|e| panic!("tcp coreset run under {wire:?}: {e}"));
        assert_eq!(t.solution, cs.solution, "tcp {wire:?}");
        assert_eq!(t.value.to_bits(), cs.value.to_bits(), "tcp {wire:?}");
        assert_eq!(t.total_calls, cs.total_calls, "tcp {wire:?}");
    }
}

#[test]
fn coreset_and_full_runs_are_distinct_cache_identities() {
    // A coreset answer is a different result, not a cheaper route to the
    // same one: the leaf greedy sees only the coreset, so its call count
    // must drop against the full run on the same instance.
    let parsed = Config::parse(CORESET_SPEC).unwrap();
    let problem = build_problem(&parsed, None).unwrap();
    let (constraint, _k) = build_constraint(&parsed, problem.oracle.n()).unwrap();
    let base = DistConfig::greedyml(AccumulationTree::new(4, 2), SEED);
    let full_cfg = DistConfig { backend: BackendSpec::Thread, ..base.clone() };
    let full = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &full_cfg).unwrap();
    let cs_cfg = DistConfig { coreset: CoresetSpec::On, ..full_cfg };
    let cs = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &cs_cfg).unwrap();
    assert!(
        cs.total_calls != full.total_calls || cs.solution != full.solution,
        "coreset mode was a no-op on this instance"
    );
    // And the leaf-level memory the meter charges shrinks: peak worker
    // memory under coreset must not exceed the full run's.
    assert!(
        cs.machines.iter().map(|m| m.peak_mem).max()
            <= full.machines.iter().map(|m| m.peak_mem).max(),
        "coreset peak mem exceeds full-run peak mem"
    );
}

#[test]
fn incremental_coreset_resolve_matches_cold_coreset_resolve() {
    // Deltas and coresets compose: after an in-place advance, a coreset
    // solve on the warm fleet must equal a coreset solve on a cold fleet —
    // the shards are bit-identical, so the sieve passes are too.
    let (mut live, deltas) = live_fixture();
    let c = Cardinality::new(K);
    let cfg_at = |epoch: u64| DistConfig {
        coreset: CoresetSpec::On,
        ..process_cfg(epoch, WireSpec::Binary)
    };
    let warm_pool = SessionPool::new();
    run_dist_pooled_live(live.oracle(), &c, &cfg_at(0), &warm_pool, Some(&live))
        .expect("epoch-0 coreset run");
    live.apply(&deltas[0]).unwrap();
    let inc = run_dist_pooled_live(live.oracle(), &c, &cfg_at(1), &warm_pool, Some(&live))
        .expect("incremental coreset re-solve");
    assert!(inc.warm);
    let cold_pool = SessionPool::new();
    let cold = run_dist_pooled_live(live.oracle(), &c, &cfg_at(1), &cold_pool, Some(&live))
        .expect("cold coreset re-solve");
    assert_eq!(inc.outcome.solution, cold.outcome.solution);
    assert_eq!(inc.outcome.value.to_bits(), cold.outcome.value.to_bits());
}
