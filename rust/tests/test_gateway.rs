//! The gateway daemon end to end, over real processes and real sockets:
//! a `greedyml gateway` binary schedules concurrent clients' jobs onto
//! live `greedyml serve` worker daemons, and every answer must be
//! bit-identical to the same job run directly on the thread backend —
//! the backend-parity guarantee extended through the network front door.
//!
//! Fault isolation is the second contract under test: one client's
//! worker fleet dying (scripted via a `GREEDYML_FAULT_PLAN` on its
//! daemon) must not poison another client's in-flight job, and must not
//! kill the gateway.

use greedyml::algo::{run_dist, DistConfig};
use greedyml::coordinator::experiment::build_constraint;
use greedyml::coordinator::gateway::FromGateway;
use greedyml::coordinator::{build_problem, GatewayClient, JobSpec};
use greedyml::dist::BackendSpec;
use greedyml::tree::AccumulationTree;
use greedyml::util::config::Config;
use greedyml::ElemId;
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};

/// One spawned `greedyml` daemon (`serve` or `gateway`) on an ephemeral
/// localhost port, killed on drop.  Never inherits this process's
/// `GREEDYML_FAULT_PLAN`: only the daemons given a plan explicitly are
/// doomed.
struct Daemon {
    child: Child,
    addr: String,
}

impl Daemon {
    fn spawn(args: &[&str], env: &[(&str, &str)]) -> Self {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_greedyml"));
        cmd.args(args).env_remove("GREEDYML_FAULT_PLAN").stdout(Stdio::piped());
        for (k, v) in env {
            cmd.env(k, v);
        }
        let mut child = cmd.spawn().expect("spawn greedyml daemon");
        let mut line = String::new();
        BufReader::new(child.stdout.as_mut().expect("piped stdout"))
            .read_line(&mut line)
            .expect("read listen banner");
        let addr = line.trim().rsplit(' ').next().unwrap_or_default().to_string();
        assert!(
            line.contains("listening on") && addr.contains(':'),
            "unexpected daemon banner: {line:?}"
        );
        Daemon { child, addr }
    }

    fn serve(env: &[(&str, &str)]) -> Self {
        Self::spawn(&["serve", "--bind", "127.0.0.1:0"], env)
    }

    fn gateway() -> Self {
        Self::spawn(&["gateway", "--bind", "127.0.0.1:0", "--workers", "2"], &[])
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spec_with_k(k: usize) -> String {
    format!("dataset.kind = retail\ndataset.n = 400\ndataset.seed = 2\nproblem.k = {k}\n")
}

/// A 4×b2 job over `spec`; `hosts`/`seed` are patched per test via
/// struct update.
fn job(id: u64, spec: &str, backend: &str, on_fault: &str) -> JobSpec {
    JobSpec {
        id,
        spec: spec.to_string(),
        seed: 42,
        machines: 4,
        branching: 2,
        backend: backend.to_string(),
        ship: "auto".to_string(),
        hosts: None,
        threads: 2,
        local_view: false,
        on_fault: on_fault.to_string(),
        wire: "auto".to_string(),
        epoch: 0,
        coreset: "auto".to_string(),
    }
}

/// The ground truth: the same job run directly on the thread backend.
fn direct_thread_run(spec: &str, seed: u64) -> (Vec<ElemId>, f64) {
    let cfg = Config::parse(spec).unwrap();
    let problem = build_problem(&cfg, None).unwrap();
    let (constraint, _k) = build_constraint(&cfg, problem.oracle.n()).unwrap();
    let dist = DistConfig {
        backend: BackendSpec::Thread,
        ..DistConfig::greedyml(AccumulationTree::new(4, 2), seed)
    };
    let out = run_dist(problem.oracle.as_ref(), constraint.as_ref(), &dist).unwrap();
    (out.solution, out.value)
}

/// Drain acks until the next terminal frame (result/rejected/failed).
fn next_terminal(client: &mut GatewayClient) -> FromGateway {
    loop {
        match client.next().expect("gateway reply") {
            FromGateway::Accepted { .. } => continue,
            other => return other,
        }
    }
}

#[test]
fn concurrent_clients_get_bit_identical_answers_and_share_the_cache() {
    // Two serve daemons form the worker fleet; one gateway schedules two
    // clients' tcp-backend jobs onto it concurrently (two scheduler
    // workers, two different ks so neither is a cache hit of the other).
    // Each client then resubmits its job verbatim and must be answered
    // from the shared solution cache, bit-identically.
    let serve_a = Daemon::serve(&[]);
    let serve_b = Daemon::serve(&[]);
    let gateway = Daemon::gateway();
    let hosts = vec![serve_a.addr.clone(), serve_b.addr.clone()];

    let clients: Vec<_> = [6usize, 9]
        .into_iter()
        .map(|k| {
            let addr = gateway.addr.clone();
            let hosts = hosts.clone();
            std::thread::spawn(move || {
                let spec = spec_with_k(k);
                let mut client = GatewayClient::connect(&addr).unwrap();
                let fresh = JobSpec { hosts: Some(hosts), ..job(0, &spec, "tcp", "fail") };
                client.submit(&fresh).unwrap();
                let (solution, value) = match next_terminal(&mut client) {
                    FromGateway::Result { solution, value, cached: false, faults, .. } => {
                        assert!(faults.is_empty(), "clean run, no faults: {faults}");
                        (solution, value)
                    }
                    other => panic!("k={k}: expected a fresh result, got {other:?}"),
                };
                client.submit(&JobSpec { id: 1, ..fresh }).unwrap();
                match next_terminal(&mut client) {
                    FromGateway::Result { id: 1, solution: s, value: v, cached: true, .. } => {
                        assert_eq!(s, solution, "k={k}: cache replays the solution");
                        assert_eq!(v.to_bits(), value.to_bits(), "k={k}: cache replays f(S)");
                    }
                    other => panic!("k={k}: expected a cached result, got {other:?}"),
                }
                (k, solution, value)
            })
        })
        .collect();

    for handle in clients {
        let (k, solution, value) = handle.join().expect("client thread");
        let (direct_sol, direct_val) = direct_thread_run(&spec_with_k(k), 42);
        assert_eq!(solution, direct_sol, "k={k}: gateway answer matches the thread backend");
        assert_eq!(value.to_bits(), direct_val.to_bits(), "k={k}: f(S) is bit-identical");
    }
}

#[test]
fn a_killed_fleet_is_one_jobs_problem_not_the_daemons() {
    // Machines 1 and 3 of the faulted client's fleet land on the doomed
    // daemon (round-robin over the hosts ring); its plan kills machine
    // 1's session at its Leaf command.  Under `on_fault = retry` the
    // session pool migrates the dead machine to the next host in the
    // ring — the healthy daemon — and the answer must not change.  A
    // bystander client's thread-backend job in flight at the same time
    // (different seed, so the shared cache cannot serve it) must be
    // untouched, and the gateway must survive to serve a third job.
    let healthy = Daemon::serve(&[]);
    let doomed = Daemon::serve(&[("GREEDYML_FAULT_PLAN", "kill:m1@leaf")]);
    let gateway = Daemon::gateway();
    let spec = spec_with_k(8);
    let hosts = vec![healthy.addr.clone(), doomed.addr.clone()];

    let faulted = std::thread::spawn({
        let addr = gateway.addr.clone();
        let (spec, hosts) = (spec.clone(), hosts.clone());
        move || {
            let mut client = GatewayClient::connect(&addr).unwrap();
            let tcp_job = JobSpec { hosts: Some(hosts), ..job(0, &spec, "tcp", "retry") };
            client.submit(&tcp_job).unwrap();
            next_terminal(&mut client)
        }
    });
    let bystander = std::thread::spawn({
        let addr = gateway.addr.clone();
        let spec = spec.clone();
        move || {
            let mut client = GatewayClient::connect(&addr).unwrap();
            let clean = JobSpec { seed: 7, ..job(0, &spec, "thread", "fail") };
            client.submit(&clean).unwrap();
            next_terminal(&mut client)
        }
    });

    let (retry_sol, retry_val) = direct_thread_run(&spec, 42);
    match faulted.join().expect("faulted client thread") {
        FromGateway::Result { solution, value, faults, .. } => {
            assert_eq!(solution, retry_sol, "retry must not change the answer");
            assert_eq!(value.to_bits(), retry_val.to_bits());
            assert!(!faults.is_empty(), "the survived fault must be accounted");
        }
        other => panic!("faulted client expected a result, got {other:?}"),
    }
    let (clean_sol, clean_val) = direct_thread_run(&spec, 7);
    match bystander.join().expect("bystander client thread") {
        FromGateway::Result { solution, value, faults, .. } => {
            assert_eq!(solution, clean_sol, "the bystander's answer is its own");
            assert_eq!(value.to_bits(), clean_val.to_bits());
            assert!(faults.is_empty(), "the bystander saw no fault: {faults}");
        }
        other => panic!("bystander expected a result, got {other:?}"),
    }

    let mut client = GatewayClient::connect(&gateway.addr).unwrap();
    let probe = JobSpec { seed: 11, ..job(2, &spec, "thread", "fail") };
    client.submit(&probe).unwrap();
    let (third_sol, third_val) = direct_thread_run(&spec, 11);
    match next_terminal(&mut client) {
        FromGateway::Result { solution, value, .. } => {
            assert_eq!(solution, third_sol, "the daemon still serves jobs after the fault");
            assert_eq!(value.to_bits(), third_val.to_bits());
        }
        other => panic!("the daemon must survive a poisoned fleet, got {other:?}"),
    }
}
