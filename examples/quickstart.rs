//! Quickstart: maximize coverage of a synthetic transaction dataset with
//! the sequential GREEDY, RandGreeDI, and GreedyML over three tree shapes,
//! and print the paper-style comparison table.
//!
//!     cargo run --release --example quickstart

use greedyml::algo::{run_randgreedi, run_greedyml, run_sequential, randgreedi::RandGreediOpts, DistConfig};
use greedyml::constraint::Cardinality;
use greedyml::data::gen::{transactions, TransactionParams};
use greedyml::greedy::GreedyKind;
use greedyml::metrics::RunReport;
use greedyml::objective::KCover;
use greedyml::tree::AccumulationTree;
use std::sync::Arc;

fn main() -> greedyml::Result<()> {
    // 1. A kosarak-like synthetic itemset collection (see DESIGN.md §2 for
    //    the substitution rationale).
    let data = Arc::new(transactions(TransactionParams::kosarak_like(20_000), 7));
    println!(
        "dataset: {} transactions, {} items, avg itemset size {:.1}",
        data.num_sets(),
        data.num_items(),
        data.avg_set_size()
    );

    // 2. The k-cover oracle and a cardinality constraint.
    let oracle = KCover::new(data);
    let k = 200;
    let constraint = Cardinality::new(k);

    // 3. Run the three algorithms.
    let mut reports = Vec::new();

    let seq = run_sequential(&oracle, &constraint, GreedyKind::Lazy, None)
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let baseline = seq.greedy.value;
    reports.push(RunReport {
        algo: "Greedy".into(),
        dataset: "kosarak-like".into(),
        k,
        machines: 1,
        branching: 0,
        levels: 0,
        value: seq.greedy.value,
        rel_value_pct: Some(100.0),
        critical_calls: seq.greedy.calls,
        total_calls: seq.greedy.calls,
        comp_secs: seq.secs,
        comm_secs: 0.0,
        peak_mem: seq.peak_mem,
    });

    let m = 16;
    let rg = run_randgreedi(&oracle, &constraint, RandGreediOpts::new(m, 42))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    reports.push(
        RunReport::from_outcome("RandGreeDI", "kosarak-like", k, &rg, m, m, 1)
            .with_baseline(baseline),
    );

    for b in [4u32, 2] {
        let tree = AccumulationTree::new(m, b);
        let cfg = DistConfig::greedyml(tree, 42);
        let out = run_greedyml(&oracle, &constraint, &cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
        reports.push(
            RunReport::from_outcome(
                &format!("GreedyML(b={b})"),
                "kosarak-like",
                k,
                &out,
                m,
                b,
                tree.levels(),
            )
            .with_baseline(baseline),
        );
    }

    // 4. Print the table.
    println!("\n{}", RunReport::header());
    for r in &reports {
        println!("{}", r.row());
    }
    println!(
        "\nNote how GreedyML keeps the objective within ~1% of RandGreeDI while \
         the critical-path call count and peak accumulation memory drop as b shrinks."
    );
    Ok(())
}
