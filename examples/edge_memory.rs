//! The §6.2 memory-limit scenario: a per-machine budget small enough that
//! RandGreeDI's single accumulation step cannot hold the m·k child
//! solutions, while GreedyML's taller trees fit — the paper's headline
//! "solves problems the others cannot" result, reproduced as real OOM
//! errors from the memory meter.
//!
//!     cargo run --release --example edge_memory

use greedyml::algo::{run_greedyml, run_sequential, DistConfig};
use greedyml::constraint::Cardinality;
use greedyml::data::gen::{barabasi_albert};
use greedyml::greedy::GreedyKind;
use greedyml::objective::KDominatingSet;
use greedyml::tree::AccumulationTree;
use greedyml::util::fmt_bytes;
use std::sync::Arc;

fn main() -> greedyml::Result<()> {
    let g = Arc::new(barabasi_albert(60_000, 3, 3));
    let oracle = KDominatingSet::new(g);
    let k = 1500;
    let constraint = Cardinality::new(k);
    let m = 16u32;

    // Pick a budget from an unlimited probe: enough for every leaf, not
    // enough for the RandGreeDI root accumulation (the paper sizes its
    // 100 MB / 1-4 GB limits the same way, §6.2.2).
    let probe = run_greedyml(
        &oracle,
        &constraint,
        &DistConfig::greedyml(AccumulationTree::randgreedi(m), 1),
    )
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    let root_peak = probe.machines[0].peak_mem;
    let leaf_peak = probe.machines[1..].iter().map(|s| s.peak_mem).max().unwrap();
    let limit = leaf_peak + (root_peak - leaf_peak) / 2;
    println!(
        "probe: leaf peak {}, RandGreeDI root peak {} → per-machine limit {}",
        fmt_bytes(leaf_peak),
        fmt_bytes(root_peak),
        fmt_bytes(limit)
    );

    // Sequential Greedy: cannot even hold the dataset under this limit.
    match run_sequential(&oracle, &constraint, GreedyKind::Lazy, Some(limit)) {
        Err(e) => println!("\nGreedy          → {e}"),
        Ok(_) => println!("\nGreedy          → unexpectedly fit"),
    }

    println!("{:<15} {:>3} {:>3} {:>12} {:>14} {:>12}", "algo", "b", "L", "f(S)", "peak mem", "crit calls");
    for b in [m, 8, 4, 2] {
        let tree = AccumulationTree::new(m, b);
        let cfg = DistConfig {
            mem_limit: Some(limit),
            ..DistConfig::greedyml(tree, 1)
        };
        let label = if b == m { "RandGreeDI" } else { "GreedyML" };
        match run_greedyml(&oracle, &constraint, &cfg) {
            Ok(out) => println!(
                "{:<15} {:>3} {:>3} {:>12.0} {:>14} {:>12}",
                label,
                b,
                tree.levels(),
                out.value,
                fmt_bytes(out.peak_mem()),
                out.critical_calls
            ),
            Err(e) => println!("{label:<15} {b:>3} {:>3} OOM: {e}", tree.levels()),
        }
    }
    println!(
        "\nGreedyML with smaller branching factors fits the same budget by \
         accumulating fewer solutions per level — at the cost of more levels \
         (more critical-path calls), exactly the Fig. 5 / Table 3 trade-off."
    );
    Ok(())
}
