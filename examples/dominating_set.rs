//! k-dominating set on a road-network-like graph: the paper's §6.1 setting
//! in miniature — fixed machines, sweep (L, b) and k, watch critical-path
//! calls and quality.
//!
//!     cargo run --release --example dominating_set

use greedyml::algo::{run_greedyml, run_sequential, DistConfig};
use greedyml::constraint::Cardinality;
use greedyml::data::gen::{road, RoadParams};
use greedyml::data::DatasetSummary;
use greedyml::greedy::GreedyKind;
use greedyml::objective::KDominatingSet;
use greedyml::tree::AccumulationTree;
use std::sync::Arc;

fn main() -> greedyml::Result<()> {
    let g = Arc::new(road(RoadParams::usa_like(1 << 16), 5));
    println!("{}", DatasetSummary::header());
    println!("{}", DatasetSummary::of_graph("road-like", &g).row());

    let oracle = KDominatingSet::new(g);
    let m = 32;

    for k in [256usize, 1024, 4096] {
        let constraint = Cardinality::new(k);
        let seq = run_sequential(&oracle, &constraint, GreedyKind::Lazy, None)
            .map_err(|e| anyhow::anyhow!("{e}"))?;
        println!(
            "\nk = {k}: Greedy covers {} vertices with {} calls",
            seq.greedy.value, seq.greedy.calls
        );
        println!(
            "{:<14} {:>3} {:>3} {:>10} {:>14} {:>12} {:>10}",
            "algo", "L", "b", "rel f(%)", "crit calls", "vs greedy", "comp (s)"
        );
        for b in [m, 8, 4, 2] {
            let tree = AccumulationTree::new(m, b);
            let cfg = DistConfig::greedyml(tree, 9);
            let out =
                run_greedyml(&oracle, &constraint, &cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
            let label = if b == m { "RandGreeDI-eq" } else { "GreedyML" };
            println!(
                "{:<14} {:>3} {:>3} {:>10.2} {:>14} {:>11.1}% {:>10.3}",
                label,
                tree.levels(),
                b,
                100.0 * out.value / seq.greedy.value,
                out.critical_calls,
                100.0 * out.critical_calls as f64 / seq.greedy.calls as f64,
                out.comp_secs,
            );
        }
    }
    println!(
        "\nThe critical path shrinks relative to Greedy as leaves parallelize the \
         first scan; small b trades a few extra levels for far smaller accumulations."
    );
    Ok(())
}
