//! End-to-end driver (the §6.4 exemplar-clustering experiment): all three
//! layers composed on a real small workload.
//!
//! * Layer 1/2: the k-medoid Pallas kernels, AOT-compiled to
//!   `artifacts/kmedoid_*_d128.hlo.txt` (run `make artifacts` first).
//! * Runtime: the Rust PJRT engine loads and executes them.
//! * Layer 3: GreedyML distributes a Tiny-ImageNet-like dataset over 32
//!   simulated machines with the paper's local-objective scheme and
//!   compares accumulation trees (L,b) ∈ {(1,32),(2,8),(3,4),(5,2)} —
//!   Table 4's sweep — reporting relative function value and speedup vs
//!   RandGreeDI, and dumping the chosen exemplars (Fig. 7).
//!
//!     make artifacts && cargo run --release --example summarization

use greedyml::algo::{run_greedyml, run_randgreedi, randgreedi::RandGreediOpts, DistConfig};
use greedyml::constraint::Cardinality;
use greedyml::data::gen::{gaussian_mixture, GaussianParams};
use greedyml::objective::{KMedoid, Oracle};
use greedyml::runtime::{Engine, KMedoidPjrt};
use greedyml::tree::AccumulationTree;
use std::sync::Arc;

fn main() -> greedyml::Result<()> {
    let dump = std::env::args().any(|a| a == "--dump-exemplars");

    // Tiny-ImageNet-like: class-structured vectors, d = 128 (the dimension
    // the artifacts were compiled for; cf. python/compile/aot.py --dims).
    let n = 4096;
    let dim = 128;
    let (vs, labels) = gaussian_mixture(GaussianParams::tiny_imagenet_like(n, dim), 11);
    let vs = Arc::new(vs);
    println!("dataset: {n} vectors, d={dim}, {} classes", labels.iter().max().unwrap() + 1);

    // Load the AOT artifacts and build the PJRT-backed oracle. This is the
    // end-to-end proof: Python never runs here, yet the gain math executes
    // in the Pallas kernel through PJRT.
    let engine = Arc::new(Engine::load(&greedyml::runtime::artifact_dir())?);
    println!("PJRT engine: platform={}, {} entries", engine.platform(), engine.manifest().entries.len());
    let pjrt_oracle = KMedoidPjrt::new(vs.clone(), engine)?;
    let cpu_oracle = KMedoid::new(vs.clone());

    let k = 48;
    let m = 32;
    let constraint = Cardinality::new(k);

    // Baseline: RandGreeDI with the local-objective scheme (§6.4). The CPU
    // oracle is used for the baseline so the speedup column isolates tree
    // shape, not backend.
    let opts = RandGreediOpts { local_view: true, ..RandGreediOpts::new(m, 3) };
    let rg = run_randgreedi(&cpu_oracle, &constraint, opts).map_err(|e| anyhow::anyhow!("{e}"))?;
    let rg_global = cpu_oracle.eval(&rg.solution);
    println!(
        "\nRandGreeDI (m={m}): local f = {:.4}, global f = {:.4}, crit calls = {}, comp = {:.2}s",
        rg.value, rg_global, rg.critical_calls, rg.comp_secs
    );

    // Table 4 sweep: (L, b) with 32 machines.
    println!("\n{:<10} {:>3} {:>3} {:>12} {:>12} {:>10} {:>12}", "algo", "L", "b", "rel f (%)", "crit calls", "speedup", "interior |D|");
    for b in [2u32, 4, 8, 16] {
        let tree = AccumulationTree::new(m, b);
        let cfg = DistConfig { local_view: true, ..DistConfig::greedyml(tree, 3) };
        let out = run_greedyml(&cpu_oracle, &constraint, &cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
        let global = cpu_oracle.eval(&out.solution);
        println!(
            "{:<10} {:>3} {:>3} {:>12.2} {:>12} {:>10.2} {:>12}",
            "GML",
            tree.levels(),
            b,
            100.0 * global / rg_global,
            out.critical_calls,
            rg.comp_secs / out.comp_secs.max(1e-9),
            out.max_accum_elems,
        );
    }

    // The PJRT path end-to-end on the best tree (b=2): same algorithm, gain
    // math in the AOT kernel.
    let tree = AccumulationTree::new(8, 2);
    let cfg = DistConfig { local_view: true, ..DistConfig::greedyml(tree, 3) };
    let out_pjrt =
        run_greedyml(&pjrt_oracle, &constraint, &cfg).map_err(|e| anyhow::anyhow!("{e}"))?;
    let out_cpu = {
        let cfg = DistConfig { local_view: true, ..DistConfig::greedyml(tree, 3) };
        run_greedyml(&cpu_oracle, &constraint, &cfg).map_err(|e| anyhow::anyhow!("{e}"))?
    };
    let g_pjrt = cpu_oracle.eval(&out_pjrt.solution);
    let g_cpu = cpu_oracle.eval(&out_cpu.solution);
    println!(
        "\nPJRT-backed GreedyML (m=8,b=2): global f = {:.4} (CPU path: {:.4}, agreement {:.2}%)",
        g_pjrt,
        g_cpu,
        100.0 * g_pjrt / g_cpu
    );

    // Fig. 7: the exemplars. With class labels available we report how many
    // distinct classes the k exemplars span — the paper's "diverse set of
    // exemplar images" claim, quantified.
    let classes: std::collections::HashSet<u32> =
        out_pjrt.solution.iter().map(|&e| labels[e as usize]).collect();
    println!(
        "exemplar diversity: {} exemplars span {} of {} classes",
        out_pjrt.solution.len(),
        classes.len(),
        labels.iter().max().unwrap() + 1
    );
    if dump {
        println!("exemplar ids: {:?}", out_pjrt.solution);
        for &e in out_pjrt.solution.iter().take(4) {
            let row = vs.row(e as usize);
            println!("  exemplar {e} (class {}): first 8 dims {:?}", labels[e as usize], &row[..8]);
        }
    }
    Ok(())
}
