"""Turn `greedyml sweep --csv <dir>` output into the paper's figures.

The Rust sweep runner emits three long-form CSVs (rust/src/metrics.rs,
`write_sweep_csvs`):

* ``fig4_tree_params.csv``  — relative objective quality vs k per
  algorithm/tree shape (Fig. 4: GreedyML trees match RandGreeDI quality).
* ``fig5_memory_vary_k.csv`` — per-machine peak memory vs k (Fig. 5: the
  accumulation tree caps the root's footprint).
* ``fig6_strong_scaling.csv`` — runtime vs machine count (Fig. 6).

This script renders each CSV it finds into a PNG next to the data::

    cargo run --release -- sweep --config configs/fig4.toml --csv out/
    python python/plots/figures.py out/

matplotlib is gated exactly like the optional deps in the kernel tests
(`python/tests/test_kernel.py` skips without hypothesis): missing
matplotlib is a clean, explanatory exit/skip, never a traceback — the
tier-1 environment does not install it.
"""

from __future__ import annotations

import csv
import os
import sys

try:  # gated import: plotting is optional, parsing is not
    import matplotlib

    matplotlib.use("Agg")  # headless: CI and ssh sessions have no display
    import matplotlib.pyplot as plt

    HAVE_MPL = True
except ImportError:  # pragma: no cover - exercised only without matplotlib
    HAVE_MPL = False


def read_rows(path: str) -> list[dict[str, str]]:
    """Read one long-form CSV into dict rows (header-keyed)."""
    with open(path, newline="") as fh:
        return list(csv.DictReader(fh))


def _series(rows: list[dict[str, str]], x_key: str, y_key: str):
    """Group rows by algorithm label into sorted (x, y) float series.

    Rows with an empty y value (e.g. a missing rel_value_pct baseline)
    are dropped rather than plotted as zeros.
    """
    by_algo: dict[str, list[tuple[float, float]]] = {}
    for row in rows:
        y = row.get(y_key, "")
        if y == "":
            continue
        by_algo.setdefault(row["algo"], []).append((float(row[x_key]), float(y)))
    return {algo: sorted(pts) for algo, pts in by_algo.items()}


def _plot(series, *, title, xlabel, ylabel, out_path, logy=False):
    fig, ax = plt.subplots(figsize=(6.4, 4.2))
    for algo, pts in sorted(series.items()):
        xs = [p[0] for p in pts]
        ys = [p[1] for p in pts]
        ax.plot(xs, ys, marker="o", linewidth=1.6, markersize=4, label=algo)
    if logy:
        ax.set_yscale("log")
    ax.set_title(title)
    ax.set_xlabel(xlabel)
    ax.set_ylabel(ylabel)
    ax.grid(True, linewidth=0.3, alpha=0.6)
    ax.legend(fontsize=8)
    fig.tight_layout()
    fig.savefig(out_path, dpi=150)
    plt.close(fig)
    return out_path


def fig4(csv_path: str, out_dir: str) -> str:
    """Fig. 4: solution quality (percent of the sequential-greedy value)
    across k for each algorithm / tree shape."""
    series = _series(read_rows(csv_path), "k", "rel_value_pct")
    return _plot(
        series,
        title="Fig. 4 — quality vs k (tree shapes)",
        xlabel="k (solution size)",
        ylabel="f(S) / f(Greedy) [%]",
        out_path=os.path.join(out_dir, "fig4_tree_params.png"),
    )


def fig5(csv_path: str, out_dir: str) -> str:
    """Fig. 5: per-machine peak memory across k (log scale — the gap
    between RandGreeDI's wide gather and GreedyML's narrow trees is
    multiplicative)."""
    series = _series(read_rows(csv_path), "k", "peak_mem_bytes")
    return _plot(
        series,
        title="Fig. 5 — per-machine peak memory vs k",
        xlabel="k (solution size)",
        ylabel="peak memory [bytes]",
        out_path=os.path.join(out_dir, "fig5_memory_vary_k.png"),
        logy=True,
    )


def fig6(csv_path: str, out_dir: str) -> str:
    """Fig. 6: strong scaling — total (compute + communication) seconds
    against the machine count."""
    series = _series(read_rows(csv_path), "machines", "total_secs")
    return _plot(
        series,
        title="Fig. 6 — strong scaling",
        xlabel="machines m",
        ylabel="total seconds (comp + comm)",
        out_path=os.path.join(out_dir, "fig6_strong_scaling.png"),
        logy=True,
    )


RENDERERS = {
    "fig4_tree_params.csv": fig4,
    "fig5_memory_vary_k.csv": fig5,
    "fig6_strong_scaling.csv": fig6,
}


def render_all(csv_dir: str, out_dir: str | None = None) -> list[str]:
    """Render every known CSV present in ``csv_dir``; returns written paths.

    Raises a clean, explanatory RuntimeError without matplotlib (the
    gated import at the top of the module) — never a NameError from a
    half-imported plotting stack.
    """
    if not HAVE_MPL:
        raise RuntimeError(
            "figures.py: matplotlib is not installed — `pip install matplotlib` "
            "to render; the sweep CSVs themselves need no extra deps."
        )
    out_dir = out_dir or csv_dir
    os.makedirs(out_dir, exist_ok=True)
    written = []
    for name, renderer in sorted(RENDERERS.items()):
        path = os.path.join(csv_dir, name)
        if os.path.exists(path):
            written.append(renderer(path, out_dir))
    return written


def main(argv: list[str]) -> int:
    if len(argv) < 2 or argv[1] in ("-h", "--help"):
        print(__doc__)
        print("usage: python python/plots/figures.py <csv_dir> [out_dir]")
        return 2
    csv_dir = argv[1]
    out_dir = argv[2] if len(argv) > 2 else csv_dir
    try:
        written = render_all(csv_dir, out_dir)
    except RuntimeError as e:
        print(e, file=sys.stderr)
        return 1
    if not written:
        print(
            f"figures.py: no sweep CSVs in {csv_dir} (expected any of: "
            + ", ".join(sorted(RENDERERS))
            + ") — run `greedyml sweep --config … --csv {csv_dir}` first.",
            file=sys.stderr,
        )
        return 1
    for path in written:
        print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
