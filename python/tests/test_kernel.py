"""Pallas kernels vs pure-jnp references — the core L1 correctness signal.

Hypothesis sweeps shapes (and the data distribution) and asserts allclose
against ref.py.  Tolerances are loose-ish (1e-4) because the matmul
expansion ‖x‖²+‖c‖²−2x·c is less numerically stable than the direct
difference — this is the same trade the TPU kernel makes.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property sweeps need hypothesis")
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.coverage import coverage_gains
from compile.kernels.kmedoid import kmedoid_gains, kmedoid_update
from compile.kernels.ref import (
    coverage_gains_ref,
    kmedoid_gains_ref,
    kmedoid_update_ref,
)

RNG = np.random.default_rng(0)


def _mk_kmedoid(n, d, k, seed, mind_scale=2.0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d), dtype=np.float32)
    mind = (mind_scale * rng.random(n)).astype(np.float32)
    c = rng.standard_normal((k, d), dtype=np.float32)
    return x, mind, c


# ---------------------------------------------------------------- kmedoid


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(1, 3),
    n_tile=st.sampled_from([8, 32, 128]),
    d=st.sampled_from([4, 16, 64]),
    k=st.integers(1, 9),
    seed=st.integers(0, 2**31 - 1),
)
def test_kmedoid_gains_matches_ref(tiles, n_tile, d, k, seed):
    n = tiles * n_tile
    x, mind, c = _mk_kmedoid(n, d, k, seed)
    got = kmedoid_gains(x, mind, c, n_tile=n_tile)
    want = kmedoid_gains_ref(jnp.asarray(x), jnp.asarray(mind), jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(1, 3),
    n_tile=st.sampled_from([8, 64]),
    d=st.sampled_from([4, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kmedoid_update_matches_ref(tiles, n_tile, d, seed):
    n = tiles * n_tile
    x, mind, c = _mk_kmedoid(n, d, 1, seed)
    cand = c[0]
    got = kmedoid_update(x, mind, cand, n_tile=n_tile)
    want = kmedoid_update_ref(jnp.asarray(x), jnp.asarray(mind), jnp.asarray(cand))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_kmedoid_padded_rows_contribute_zero():
    # Padding convention: rows with mind=0 add exactly 0 gain.
    x, mind, c = _mk_kmedoid(64, 8, 4, seed=3)
    mind[32:] = 0.0
    full = kmedoid_gains(x, mind, c, n_tile=32)
    only_live = kmedoid_gains_ref(
        jnp.asarray(x[:32]), jnp.asarray(mind[:32]), jnp.asarray(c)
    )
    np.testing.assert_allclose(np.asarray(full), np.asarray(only_live), rtol=1e-4, atol=1e-4)


def test_kmedoid_gains_additive_over_chunks():
    # The rust runtime chunks big views and sums gains — verify additivity.
    x, mind, c = _mk_kmedoid(96, 8, 5, seed=7)
    whole = kmedoid_gains(x, mind, c, n_tile=32)
    parts = sum(
        np.asarray(kmedoid_gains(x[i : i + 32], mind[i : i + 32], c, n_tile=32))
        for i in range(0, 96, 32)
    )
    np.testing.assert_allclose(np.asarray(whole), parts, rtol=1e-4, atol=1e-4)


def test_kmedoid_rejects_ragged_n():
    x, mind, c = _mk_kmedoid(48, 8, 2, seed=1)
    with pytest.raises(AssertionError):
        kmedoid_gains(x, mind, c, n_tile=32)


def test_kmedoid_gain_is_nonnegative_and_zero_for_committed():
    x, mind, c = _mk_kmedoid(64, 16, 8, seed=11)
    gains = np.asarray(kmedoid_gains(x, mind, c, n_tile=64))
    assert (gains >= 0).all()
    # Committing candidate 0 then re-evaluating it yields ~0 gain.
    mind2 = np.asarray(kmedoid_update(x, mind, c[0], n_tile=64))
    regain = np.asarray(kmedoid_gains(x, mind2, c, n_tile=64))
    assert regain[0] == pytest.approx(0.0, abs=1e-4)
    assert (regain <= gains + 1e-4).all(), "gains must diminish after commit"


# --------------------------------------------------------------- coverage


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(1, 3),
    w_tile=st.sampled_from([4, 16, 64]),
    k=st.integers(1, 9),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_coverage_matches_ref(tiles, w_tile, k, density, seed):
    rng = np.random.default_rng(seed)
    w = tiles * w_tile
    masks = (rng.random((k, w)) < density).astype(np.uint32)
    # Random bit patterns, not just 0/1 words.
    masks = (masks * rng.integers(0, 2**32, (k, w), dtype=np.uint64)).astype(np.uint32)
    covered = rng.integers(0, 2**32, (w,), dtype=np.uint64).astype(np.uint32)
    got = coverage_gains(masks, covered, w_tile=w_tile)
    want = coverage_gains_ref(jnp.asarray(masks), jnp.asarray(covered))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_coverage_hand_case():
    # candidate covers bits {0,1,32}; covered has bit 0 → gain 2.
    masks = np.zeros((2, 2), dtype=np.uint32)
    masks[0, 0] = 0b11
    masks[0, 1] = 0b1
    covered = np.array([0b1, 0], dtype=np.uint32)
    got = np.asarray(coverage_gains(masks, covered, w_tile=2))
    assert got.tolist() == [2, 0]


def test_coverage_full_overlap_is_zero():
    rng = np.random.default_rng(5)
    masks = rng.integers(0, 2**32, (4, 8), dtype=np.uint64).astype(np.uint32)
    covered = np.full(8, 0xFFFFFFFF, dtype=np.uint32)
    got = np.asarray(coverage_gains(masks, covered, w_tile=8))
    assert (got == 0).all()
