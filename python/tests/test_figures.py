"""python/plots/figures.py against synthetic sweep CSVs.

The parsing/grouping layer runs everywhere; the rendering tests are
gated on matplotlib exactly like the kernel tests gate on hypothesis —
the tier-1 image does not ship it.
"""

import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "plots"))
import figures  # noqa: E402

FIG4 = """algo,dataset,k,machines,branching,levels,value,rel_value_pct,critical_calls
Greedy,retail,4,1,0,0,100.0,100,400
"GML(m=8,b=2,L=3)",retail,4,8,2,3,97.0,97,120
Greedy,retail,8,1,0,0,150.0,100,800
"GML(m=8,b=2,L=3)",retail,8,8,2,3,148.5,99,260
"""

FIG5 = """algo,dataset,k,machines,branching,levels,peak_mem_bytes
RG(m=8),retail,4,8,8,1,4096
RG(m=8),retail,8,8,8,1,8192
"GML(m=8,b=2,L=3)",retail,4,8,2,3,1024
"GML(m=8,b=2,L=3)",retail,8,8,2,3,2048
"""

FIG6 = """algo,dataset,k,machines,levels,comp_secs,comm_secs,total_secs,critical_calls
RG(m=4),retail,8,4,1,0.5,0.01,0.51,900
RG(m=8),retail,8,8,1,0.3,0.02,0.32,500
"""


def write_csvs(tmp_path, names):
    texts = {
        "fig4_tree_params.csv": FIG4,
        "fig5_memory_vary_k.csv": FIG5,
        "fig6_strong_scaling.csv": FIG6,
    }
    for name in names:
        (tmp_path / name).write_text(texts[name])


def test_series_groups_by_algo_and_drops_blank_values(tmp_path):
    write_csvs(tmp_path, ["fig4_tree_params.csv"])
    rows = figures.read_rows(str(tmp_path / "fig4_tree_params.csv"))
    assert len(rows) == 4
    series = figures._series(rows, "k", "rel_value_pct")
    assert set(series) == {"Greedy", "GML(m=8,b=2,L=3)"}
    assert series["GML(m=8,b=2,L=3)"] == [(4.0, 97.0), (8.0, 99.0)]
    # A blank y cell (no baseline yet) is dropped, not plotted as zero.
    rows[0]["rel_value_pct"] = ""
    assert len(figures._series(rows, "k", "rel_value_pct")["Greedy"]) == 1


def test_render_all_without_csvs_is_empty(tmp_path):
    pytest.importorskip("matplotlib", reason="rendering needs matplotlib")
    assert figures.render_all(str(tmp_path)) == []


def test_render_all_writes_one_png_per_present_csv(tmp_path):
    pytest.importorskip("matplotlib", reason="rendering needs matplotlib")
    write_csvs(
        tmp_path,
        ["fig4_tree_params.csv", "fig5_memory_vary_k.csv", "fig6_strong_scaling.csv"],
    )
    out = tmp_path / "png"
    written = figures.render_all(str(tmp_path), str(out))
    assert [os.path.basename(p) for p in written] == [
        "fig4_tree_params.png",
        "fig5_memory_vary_k.png",
        "fig6_strong_scaling.png",
    ]
    for p in written:
        assert os.path.getsize(p) > 1000, f"{p} looks empty"
