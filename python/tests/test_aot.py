"""AOT path: entry points lower to HLO text, manifest is consistent, and
the HLO text re-parses through xla_client (the same parser family the Rust
runtime uses via HloModuleProto::from_text_file)."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot


def test_entry_points_cover_all_models():
    eps = aot.entry_points([64])
    names = [n for n, _, _ in eps]
    assert names == [
        "kmedoid_gains_d64",
        "kmedoid_update_d64",
        "kmedoid_step_d64",
        "coverage_gains",
    ]


def test_lowering_produces_hlo_text():
    import jax

    name, fn, example = aot.entry_points([64])[0]
    text = aot.to_hlo_text(jax.jit(fn).lower(*example))
    assert "HloModule" in text
    assert "ROOT" in text
    # Tuple root (return_tuple=True) so rust's to_tuple1 works.
    assert "tuple(" in text.replace(" ", "")


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    cmd = [
        sys.executable,
        "-m",
        "compile.aot",
        "--out-dir",
        str(out),
        "--dims",
        "8",
    ]
    env = dict(os.environ)
    subprocess.run(cmd, check=True, cwd=os.path.dirname(os.path.dirname(__file__)), env=env)
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert len(manifest["entries"]) == 4
    for e in manifest["entries"]:
        path = out / e["file"]
        assert path.exists(), e["file"]
        text = path.read_text()
        assert text.startswith("HloModule")
        assert e["inputs"], "inputs recorded"
        assert e["outputs"], "outputs recorded"


def test_manifest_shapes_match_tiles():
    eps = aot.entry_points([16])
    for name, _, example in eps:
        if name.startswith("kmedoid_gains"):
            x, mind, c = example
            assert x.shape[0] == aot.N_TILE
            assert c.shape[0] == aot.C_TILE
        if name == "coverage_gains":
            masks, covered = example
            assert masks.shape == (aot.C_TILE, aot.W_TILE)
            assert covered.shape == (aot.W_TILE,)
