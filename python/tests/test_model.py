"""Layer-2 model graphs: shapes and semantics of the AOT entry points."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model
from compile.kernels.ref import kmedoid_gains_ref, kmedoid_update_ref


def _data(n=64, d=8, k=6, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d), dtype=np.float32)
    mind = (2.0 * rng.random(n)).astype(np.float32)
    c = rng.standard_normal((k, d), dtype=np.float32)
    return x, mind, c


def test_gains_model_is_tuple_wrapped():
    x, mind, c = _data(n=256, d=8)
    (gains,) = model.kmedoid_gains_model(x, mind, c)
    want = kmedoid_gains_ref(jnp.asarray(x), jnp.asarray(mind), jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(gains), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_update_model():
    x, mind, c = _data(n=256, d=8)
    (new_mind,) = model.kmedoid_update_model(x, mind, c[0])
    want = kmedoid_update_ref(jnp.asarray(x), jnp.asarray(mind), jnp.asarray(c[0]))
    np.testing.assert_allclose(np.asarray(new_mind), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_step_model_selects_argmax_and_commits():
    x, mind, c = _data(n=256, d=8, k=5, seed=3)
    best, gain, new_mind = model.kmedoid_step_model(x, mind, c)
    gains = np.asarray(kmedoid_gains_ref(jnp.asarray(x), jnp.asarray(mind), jnp.asarray(c)))
    assert int(best) == int(np.argmax(gains))
    assert float(gain) == pytest.approx(float(gains.max()), rel=1e-4)
    want = kmedoid_update_ref(
        jnp.asarray(x), jnp.asarray(mind), jnp.asarray(c[int(best)])
    )
    np.testing.assert_allclose(np.asarray(new_mind), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_step_model_no_improvement_keeps_mind():
    x, mind, c = _data(n=256, d=8, k=3, seed=4)
    mind[:] = 0.0  # nothing can improve a zero-loss view
    best, gain, new_mind = model.kmedoid_step_model(x, mind, c)
    assert float(gain) == 0.0
    np.testing.assert_array_equal(np.asarray(new_mind), mind)


def test_coverage_model():
    rng = np.random.default_rng(9)
    masks = rng.integers(0, 2**32, (4, 1024), dtype=np.uint64).astype(np.uint32)
    covered = rng.integers(0, 2**32, (1024,), dtype=np.uint64).astype(np.uint32)
    (gains,) = model.coverage_gains_model(masks, covered)
    fresh = masks & ~covered[None, :]
    want = np.array([sum(int(v).bit_count() for v in row) for row in fresh])
    np.testing.assert_array_equal(np.asarray(gains), want)
