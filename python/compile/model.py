"""Layer-2 JAX compute graphs over the Layer-1 Pallas kernels.

These are the jitted functions that get AOT-lowered (aot.py) and executed
from the Rust coordinator's hot path via PJRT.  Each is a thin composition
around a kernel so the kernel lowers *into the same HLO module* — Python
never runs at solve time.

Graphs:
  * `kmedoid_gains_model`   — batched candidate gains for one view chunk.
  * `kmedoid_update_model`  — fold a committed candidate into `mind`.
  * `kmedoid_step_model`    — fused gains + argmax + update: one greedy
    round in a single executable launch (the §Perf L2 fusion — avoids a
    host round-trip between selecting and committing).
  * `coverage_gains_model`  — packed-bitmap coverage gains.
"""

import jax
import jax.numpy as jnp

from compile.kernels.coverage import coverage_gains
from compile.kernels.kmedoid import kmedoid_gains, kmedoid_update


def kmedoid_gains_model(x, mind, c):
    """Candidate gain sums for one padded view chunk (see kernels.kmedoid)."""
    return (kmedoid_gains(x, mind, c),)


def kmedoid_update_model(x, mind, cand):
    """Updated min-distance vector after committing `cand`."""
    return (kmedoid_update(x, mind, cand),)


def kmedoid_step_model(x, mind, c):
    """One fused greedy round over a candidate tile.

    Args:
      x:    [n, d] f32 padded view chunk.
      mind: [n] f32.
      c:    [kc, d] f32 candidate tile (pad unused rows with zeros AND mark
            them invalid by passing x rows with mind=0 — padded candidates
            produce gain 0 and lose the argmax unless all gains are 0).

    Returns:
      (best_idx i32, best_gain f32, new_mind [n] f32) — new_mind already
      reflects committing the argmax candidate.
    """
    gains = kmedoid_gains(x, mind, c)  # [kc]
    best = jnp.argmax(gains)
    best_gain = gains[best]
    new_mind = kmedoid_update(x, mind, c[best])
    # If nothing improves, keep mind unchanged (commit of a useless
    # candidate is a no-op anyway since min() can only decrease, but the
    # guard keeps semantics exact for the all-zero-gain tile).
    new_mind = jnp.where(best_gain > 0.0, new_mind, mind)
    return (best.astype(jnp.int32), best_gain, new_mind)


def coverage_gains_model(masks, covered):
    """Packed-bitmap coverage gains (see kernels.coverage)."""
    return (coverage_gains(masks, covered),)
