"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: every kernel in this package must
match its reference here (pytest + hypothesis sweep shapes/dtypes), and the
Rust oracles cross-check against the same semantics through the AOT
artifacts.

Conventions shared with the Rust side (rust/src/objective, rust/src/runtime):

* k-medoid gains are *sums* over the view, not means — the caller divides by
  n' so that padded rows (mind = 0) contribute exactly zero.
* distances are Euclidean (sqrt of clamped squared distance), matching
  `KMedoid` in rust/src/objective/kmedoid.rs.
* coverage bitmaps are little-endian uint32 words; gains count candidate
  bits not present in the covered mask.
"""

import jax.numpy as jnp


def kmedoid_gains_ref(x, mind, c):
    """Gain sums for k-medoid candidates.

    Args:
      x:    [n, d] float32 — view vectors.
      mind: [n]    float32 — current min distance of each view vector to
            the solution ∪ {e0}.
      c:    [k, d] float32 — candidate vectors.

    Returns:
      [k] float32 — gains[j] = sum_i max(mind_i − ‖x_i − c_j‖, 0).
    """
    # ‖x−c‖² = ‖x‖² + ‖c‖² − 2·x@cᵀ, clamped for numerical safety.
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # [n, 1]
    c2 = jnp.sum(c * c, axis=1)[None, :]  # [1, k]
    d2 = x2 + c2 - 2.0 * x @ c.T  # [n, k]
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    improv = jnp.maximum(mind[:, None] - dist, 0.0)  # [n, k]
    return jnp.sum(improv, axis=0)


def kmedoid_update_ref(x, mind, cand):
    """New per-row min distances after committing one candidate.

    Args:
      x:    [n, d] float32.
      mind: [n]    float32.
      cand: [d]    float32 — the committed candidate.

    Returns:
      [n] float32 — elementwise min(mind, ‖x − cand‖).
    """
    diff = x - cand[None, :]
    dist = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=1), 0.0))
    return jnp.minimum(mind, dist)


def coverage_gains_ref(masks, covered):
    """Coverage gains over packed uint32 bitmaps.

    Args:
      masks:   [k, w] uint32 — candidate bitmaps.
      covered: [w]    uint32 — already-covered bitmap.

    Returns:
      [k] int32 — popcount(masks & ~covered) per candidate.
    """
    fresh = jnp.bitwise_and(masks, jnp.bitwise_not(covered)[None, :])
    pops = jnp.bitwise_count(fresh).astype(jnp.int32)
    return jnp.sum(pops, axis=1)
