"""Layer-1 Pallas kernel for packed-bitmap coverage gains.

k-cover / k-dominating-set marginal gains are popcount(cand & ~covered)
over the item/vertex universe.  The universe is packed 32 elements per
uint32 word; one grid step processes a [kc, wb] tile of candidate masks
against the matching [wb] slice of the covered bitmap — pure VPU integer
work (AND, NOT, popcount, add), no MXU involvement, so the natural tiling
is wide word-blocks streamed through VMEM.

VMEM per step (u32): kc·wb (masks) + wb (covered) + kc (acc).  With kc=64,
wb=1024 that is ≈ 65 K words ≈ 260 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

W_TILE = 1024
"""uint32 words per grid step."""


def _coverage_kernel(masks_ref, covered_ref, o_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    masks = masks_ref[...]  # [kc, wb] u32
    covered = covered_ref[...]  # [wb] u32
    fresh = jnp.bitwise_and(masks, jnp.bitwise_not(covered)[None, :])
    pops = jnp.bitwise_count(fresh).astype(jnp.int32)
    o_ref[...] += jnp.sum(pops, axis=1)


@functools.partial(jax.jit, static_argnames=("w_tile",))
def coverage_gains(masks, covered, *, w_tile=W_TILE):
    """Pallas-tiled coverage gains; see `ref.coverage_gains_ref`.

    Args:
      masks: [kc, w] uint32, w a multiple of `w_tile` (pad with zero words).
      covered: [w] uint32.

    Returns:
      [kc] int32 gains.
    """
    kc, w = masks.shape
    assert w % w_tile == 0, f"w={w} not a multiple of w_tile={w_tile}"
    grid = (w // w_tile,)
    return pl.pallas_call(
        _coverage_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((kc, w_tile), lambda i: (0, i)),
            pl.BlockSpec((w_tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((kc,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((kc,), jnp.int32),
        interpret=True,
    )(masks, covered)
