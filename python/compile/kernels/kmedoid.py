"""Layer-1 Pallas kernels for the k-medoid marginal-gain hot spot.

The paper's compute-heavy objective (Table 1: cost per call is n'·δ) reduces
to a dense distance computation.  On GPU the authors' C++ code walks the
view row by row; the TPU-shaped rethink (DESIGN.md §3) is:

* expand ‖x−c‖² = ‖x‖² + ‖c‖² − 2·x@cᵀ so the inner loop is a
  [nb, d] × [d, kc] matmul — MXU systolic-array work, not scalar loops;
* tile the view dimension `n` with a BlockSpec grid so each step holds one
  [nb, d] slab of X plus the [nb, kc] distance tile in VMEM;
* keep the gains accumulator [kc] resident across grid steps (output block
  is the same for every step — Pallas keeps it in VMEM).

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernels are lowered through the interpreter to plain
HLO.  Real-TPU efficiency is estimated from the BlockSpec footprint in
DESIGN.md §Perf.

VMEM budget per grid step (f32): nb·d (X) + nb (mind) + kc·d (C) +
nb·kc (dist tile) + kc (acc).  With nb=256, d=128, kc=64 that is
256·128 + 256 + 64·128 + 256·64 + 64 ≈ 57.6 K floats ≈ 230 KiB — far under
the ~16 MiB VMEM of a TPU core, leaving room to double-buffer the X stream.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes (also the AOT artifact shapes; rust pads to these).
N_TILE = 256
"""Rows of X processed per grid step."""


def _gains_kernel(x_ref, mind_ref, c_ref, o_ref):
    """One grid step: accumulate candidate gains for an X tile."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [nb, d]
    mind = mind_ref[...]  # [nb]
    c = c_ref[...]  # [kc, d]
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # [nb, 1]
    c2 = jnp.sum(c * c, axis=1)[None, :]  # [1, kc]
    # MXU-shaped inner product; accumulate in f32.
    xc = jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [nb, kc]
    d2 = jnp.maximum(x2 + c2 - 2.0 * xc, 0.0)
    dist = jnp.sqrt(d2)
    improv = jnp.maximum(mind[:, None] - dist, 0.0)  # [nb, kc]
    o_ref[...] += jnp.sum(improv, axis=0)


@functools.partial(jax.jit, static_argnames=("n_tile",))
def kmedoid_gains(x, mind, c, *, n_tile=N_TILE):
    """Pallas-tiled candidate gains; see `ref.kmedoid_gains_ref`.

    Args:
      x: [n, d] f32 with n a multiple of `n_tile` (callers pad; padded rows
         must carry mind = 0 so they contribute nothing).
      mind: [n] f32.
      c: [kc, d] f32.

    Returns:
      [kc] f32 gain sums.
    """
    n, d = x.shape
    kc = c.shape[0]
    assert n % n_tile == 0, f"n={n} not a multiple of n_tile={n_tile}"
    grid = (n // n_tile,)
    return pl.pallas_call(
        _gains_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_tile, d), lambda i: (i, 0)),  # stream X
            pl.BlockSpec((n_tile,), lambda i: (i,)),  # stream mind
            pl.BlockSpec((kc, d), lambda i: (0, 0)),  # C resident
        ],
        out_specs=pl.BlockSpec((kc,), lambda i: (0,)),  # acc resident
        out_shape=jax.ShapeDtypeStruct((kc,), jnp.float32),
        interpret=True,
    )(x, mind, c)


def _update_kernel(x_ref, mind_ref, cand_ref, o_ref):
    """One grid step: fold one candidate into the min-distance vector."""
    x = x_ref[...]  # [nb, d]
    cand = cand_ref[...]  # [1, d]
    diff = x - cand
    dist = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=1), 0.0))
    o_ref[...] = jnp.minimum(mind_ref[...], dist)


@functools.partial(jax.jit, static_argnames=("n_tile",))
def kmedoid_update(x, mind, cand, *, n_tile=N_TILE):
    """Pallas-tiled commit step; see `ref.kmedoid_update_ref`.

    Args:
      x: [n, d] f32, n a multiple of `n_tile`.
      mind: [n] f32.
      cand: [d] f32 — committed candidate (reshaped to [1, d] internally).

    Returns:
      [n] f32 updated min distances.
    """
    n, d = x.shape
    assert n % n_tile == 0, f"n={n} not a multiple of n_tile={n_tile}"
    grid = (n // n_tile,)
    return pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_tile, d), lambda i: (i, 0)),
            pl.BlockSpec((n_tile,), lambda i: (i,)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n_tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, mind, cand.reshape(1, d))
