"""AOT lowering: jit → StableHLO → XLA computation → HLO *text*.

HLO text (not `.serialize()` protos) is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the runtime's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Writes `artifacts/<name>.hlo.txt` per entry point and a single
`artifacts/manifest.json` describing shapes/dtypes, which
rust/src/runtime/manifest.rs consumes.  `make artifacts` only re-runs this
when the python sources change.

Usage: python -m compile.aot --out-dir ../artifacts [--dims 64,128]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.coverage import W_TILE
from compile.kernels.kmedoid import N_TILE

# Candidate-tile width shared by all gain entry points (rust pads to this).
C_TILE = 64


def to_hlo_text(lowered) -> str:
    """StableHLO module → HLO text with a tuple root (rust unwraps it)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def entry_points(dims):
    """(name, fn, example_args) for every artifact we ship.

    One k-medoid variant per feature dimension in `dims` (AOT shapes are
    static; the Rust runtime picks the artifact whose d matches the dataset
    and chunks/pads n and kc).
    """
    eps = []
    for d in dims:
        x = spec((N_TILE, d), jnp.float32)
        mind = spec((N_TILE,), jnp.float32)
        c = spec((C_TILE, d), jnp.float32)
        cand = spec((d,), jnp.float32)
        eps.append((f"kmedoid_gains_d{d}", model.kmedoid_gains_model, (x, mind, c)))
        eps.append((f"kmedoid_update_d{d}", model.kmedoid_update_model, (x, mind, cand)))
        eps.append((f"kmedoid_step_d{d}", model.kmedoid_step_model, (x, mind, c)))
    masks = spec((C_TILE, W_TILE), jnp.uint32)
    covered = spec((W_TILE,), jnp.uint32)
    eps.append(("coverage_gains", model.coverage_gains_model, (masks, covered)))
    return eps


def arg_entry(a):
    return {"shape": list(a.shape), "dtype": a.dtype.name}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--dims",
        default="64,128",
        help="comma-separated k-medoid feature dimensions to compile",
    )
    args = ap.parse_args()
    dims = [int(d) for d in args.dims.split(",") if d]
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "format": "hlo-text",
        "n_tile": N_TILE,
        "c_tile": C_TILE,
        "w_tile": W_TILE,
        "entries": [],
    }
    for name, fn, example in entry_points(dims):
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *example)
        manifest["entries"].append(
            {
                "name": name,
                "file": fname,
                "inputs": [arg_entry(a) for a in example],
                "outputs": [arg_entry(o) for o in outs],
            }
        )
        print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json with {len(manifest['entries'])} entries")


if __name__ == "__main__":
    main()
