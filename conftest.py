"""Repo-root pytest hook: make `compile.*` importable when the suite is run
as `pytest python/tests/` from the repository root (the Makefile runs it
from `python/`, where the package is already on sys.path)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
